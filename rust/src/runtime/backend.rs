//! The [`Backend`] trait: model ops at any live batch size.
//!
//! The engine calls ops with whatever batch the scheduler formed; the
//! backend maps that onto the fixed shapes the substrate offers:
//!
//! * [`XlaBackend`] — picks the smallest compiled batch bucket ≥ B, pads
//!   (padding query rows carry `q_pos = -1`, which the kernels mask into
//!   LSE-merge identities), executes the PJRT artifact, slices back.
//! * [`NativeBackend`] — executes the pure-rust ops directly (no padding).
//!
//! Both produce identical numerics (asserted by integration tests), so the
//! rest of the coordinator is backend-agnostic.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ModelConfig;
use crate::plan::{PlanExecCtx, PlanExecOut, StepPlan};
use crate::runtime::arena::TensorArena;
use crate::runtime::client::RuntimeHandle;
use crate::runtime::native::{self, Partials};
use crate::runtime::simd::{kernels_for, KernelSpec, Kernels};
use crate::tensor::Tensor;
use crate::util::threadpool::ThreadPool;

/// Model ops at live batch size (see module docs).
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    fn model(&self) -> &ModelConfig;

    /// Tokens per KV chunk.
    fn chunk_size(&self) -> usize;

    /// Largest K/V length one `chunk_attn` call can take (run coalescing
    /// target, §Perf opt 2). The coordinator may pass any `C ≤` this.
    fn max_attn_tokens(&self) -> usize {
        self.chunk_size()
    }

    /// tokens i32`[B]` × emb `[V,d]` → x `[B,d]`.
    fn embed(&self, tokens: &Tensor, emb: &Tensor) -> Result<Tensor>;

    /// x `[B,d]` → (q `[B,H,dh]`, k `[B,Hkv,dh]`, v `[B,Hkv,dh]`).
    fn qkv(&self, x: &Tensor, attn_norm: &Tensor, wq: &Tensor, wk: &Tensor,
           wv: &Tensor, pos: &[i32]) -> Result<(Tensor, Tensor, Tensor)>;

    /// Shared-KV chunk attention → unnormalized partials.
    fn chunk_attn(&self, q: &Tensor, k: &Tensor, v: &Tensor, q_pos: &[i32],
                  k_base: i32, valid: i32) -> Result<Partials>;

    /// Dispatch-aware chunk attention for *small* calls (§Perf opt 3).
    ///
    /// Decode-time unique-KV attention is a B=1 GEMV over a few dozen
    /// tokens — microseconds of math behind ~10²µs of PJRT dispatch on
    /// CPU. Below `SMALL_ATTN_UNITS` of work the native twin runs instead
    /// (same algorithm, equality asserted by the runtime tests); the
    /// Shared-KV GEMM path always stays on the compiled kernels.
    fn chunk_attn_auto(&self, q: &Tensor, k: &Tensor, v: &Tensor,
                       q_pos: &[i32], k_base: i32, valid: i32)
                       -> Result<Partials> {
        self.chunk_attn(q, k, v, q_pos, k_base, valid)
    }

    /// Out-proj + residual + FFN.
    fn post(&self, attn_o: &Tensor, x: &Tensor, wo: &Tensor,
            ffn_norm: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor)
            -> Result<Tensor>;

    /// Final norm + LM head → logits `[B,V]`.
    fn lm_head(&self, x: &Tensor, final_norm: &Tensor, w_lm: &Tensor)
               -> Result<Tensor>;

    /// Router scores `[B,C]` for C chunk embeddings `[C,Hkv,dh]`.
    fn router(&self, q: &Tensor, embs: &Tensor) -> Result<Tensor>;

    /// Pairwise LSE merge of partials.
    fn merge2(&self, a: &Partials, b: &Partials) -> Result<Partials>;

    /// Execution pool for coordinator-level fan-out (the plan executor's
    /// per-request unique-attention jobs). `None` means the backend is
    /// serial or manages its own parallelism (PJRT).
    fn exec_pool(&self) -> Option<&Arc<ThreadPool>> {
        None
    }

    /// The kernel-flavor vtable this backend's native math runs on; the
    /// plan executor also routes its LSE-merge/finalize tails through it
    /// so one backend = one flavor end to end. Defaults to the
    /// process-global flavor (`MOSKA_KERNEL`);
    /// [`NativeBackend::with_kernel`] overrides it per backend for A/B
    /// benching.
    fn kernels(&self) -> &'static Kernels {
        Kernels::global()
    }

    /// Dispatch-aware chunk attention whose output partials are staged in
    /// the step `arena` (decode plan-executor path). The default ignores
    /// the arena and delegates to [`Backend::chunk_attn_auto`] — correct
    /// for backends whose outputs are allocated elsewhere (PJRT buffers);
    /// [`NativeBackend`] overrides it to write into recycled
    /// identity-filled partials, bit-identical to the allocating kernel.
    fn chunk_attn_arena(&self, q: &Tensor, k: &Tensor, v: &Tensor,
                        q_pos: &[i32], k_base: i32, valid: i32,
                        arena: &mut TensorArena) -> Result<Partials> {
        let _ = arena;
        self.chunk_attn_auto(q, k, v, q_pos, k_base, valid)
    }

    /// Execute a decode [`StepPlan`] (the engine hot path): all layers,
    /// shared + unique attention, arena-staged. Every concrete backend
    /// delegates to [`crate::plan::exec::execute_plan`]; the method lives
    /// on the trait so a backend (e.g. a remote disagg node) can
    /// substitute its own executor for the same plan IR.
    fn exec_plan(&self, plan: &StepPlan, x: Tensor,
                 ctx: &mut PlanExecCtx<'_>) -> Result<PlanExecOut>;
}

// ---------------------------------------------------------------- helpers

/// Pad a tensor along axis 0 to `n` rows with a fill value.
fn pad0_f32(t: &Tensor, n: usize, fill: f32) -> Tensor {
    let shape = t.shape();
    let b = shape[0];
    if b == n {
        return t.clone();
    }
    let w: usize = shape[1..].iter().product();
    let mut data = Vec::with_capacity(n * w);
    data.extend_from_slice(t.as_f32());
    data.resize(n * w, fill);
    let mut s = shape.to_vec();
    s[0] = n;
    Tensor::f32(&s, data)
}

fn pad0_i32(t: &Tensor, n: usize, fill: i32) -> Tensor {
    let shape = t.shape();
    if shape[0] == n {
        return t.clone();
    }
    let w: usize = shape[1..].iter().product();
    let mut data = Vec::with_capacity(n * w);
    data.extend_from_slice(t.as_i32());
    data.resize(n * w, fill);
    let mut s = shape.to_vec();
    s[0] = n;
    Tensor::i32(&s, data)
}

fn pad_pos(pos: &[i32], n: usize) -> Tensor {
    let mut v = pos.to_vec();
    v.resize(n, -1); // padding rows are masked everywhere
    Tensor::i32(&[n], v)
}

/// Work threshold (query-rows × context-tokens) below which a chunk-
/// attention call runs natively instead of through PJRT (§Perf opt 3).
/// At tiny-model dims, 4096 units ≈ 1 query × 4 pages or 32 queries × 2
/// chunks — comfortably under the ~150µs PJRT dispatch floor measured in
/// `gemm_vs_gemv`.
pub const SMALL_ATTN_UNITS: usize = 4096;

// ------------------------------------------------------------ XlaBackend

/// Executes AOT artifacts through PJRT, bucket-padding each call.
pub struct XlaBackend {
    pub rt: RuntimeHandle,
    model: ModelConfig,
    chunk: usize,
}

impl XlaBackend {
    pub fn new(rt: RuntimeHandle) -> XlaBackend {
        let model = rt.manifest.model.clone();
        let chunk = rt.manifest.chunk;
        XlaBackend { rt, model, chunk }
    }

    fn bucket(&self, b: usize) -> Result<usize> {
        self.rt.manifest.pick_batch_bucket(b)
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn model(&self) -> &ModelConfig {
        &self.model
    }

    fn chunk_size(&self) -> usize {
        self.chunk
    }

    fn embed(&self, tokens: &Tensor, emb: &Tensor) -> Result<Tensor> {
        let b = tokens.shape()[0];
        let bb = self.bucket(b)?;
        let out = self.rt.execute(
            &format!("embed_b{bb}"),
            vec![pad0_i32(tokens, bb, 0), emb.clone()],
        )?;
        Ok(out.into_iter().next().unwrap().slice0(0, b))
    }

    fn qkv(&self, x: &Tensor, attn_norm: &Tensor, wq: &Tensor, wk: &Tensor,
           wv: &Tensor, pos: &[i32]) -> Result<(Tensor, Tensor, Tensor)> {
        let b = x.shape()[0];
        let bb = self.bucket(b)?;
        let mut out = self.rt.execute(
            &format!("qkv_b{bb}"),
            vec![
                pad0_f32(x, bb, 0.0),
                attn_norm.clone(),
                wq.clone(),
                wk.clone(),
                wv.clone(),
                pad_pos(pos, bb),
            ],
        )?;
        let v = out.pop().unwrap().slice0(0, b);
        let k = out.pop().unwrap().slice0(0, b);
        let q = out.pop().unwrap().slice0(0, b);
        Ok((q, k, v))
    }

    fn max_attn_tokens(&self) -> usize {
        *self.rt.manifest.attn_token_buckets.last().unwrap()
    }

    fn chunk_attn_auto(&self, q: &Tensor, k: &Tensor, v: &Tensor,
                       q_pos: &[i32], k_base: i32, valid: i32)
                       -> Result<Partials> {
        let work = q.shape()[0] * valid.max(0) as usize;
        if work <= SMALL_ATTN_UNITS {
            return Ok(native::chunk_attn(q, k, v, q_pos, k_base, valid));
        }
        self.chunk_attn(q, k, v, q_pos, k_base, valid)
    }

    fn chunk_attn(&self, q: &Tensor, k: &Tensor, v: &Tensor, q_pos: &[i32],
                  k_base: i32, valid: i32) -> Result<Partials> {
        // PJRT artifacts are compiled for f32 operands; packed (f16/bf16/
        // int8) K/V is widened through the scalar oracle here, so the XLA
        // path matches the native flavors bit-for-bit per dtype.
        let (kw, vw);
        let (k, v) = if k.is_packed() || v.is_packed() {
            kw = k.widen_to_f32();
            vw = v.widen_to_f32();
            (&kw, &vw)
        } else {
            (k, v)
        };
        let b = q.shape()[0];
        let bb = self.bucket(b)?;
        // K/V length buckets: pad rows beyond `valid` are masked anyway
        let c = k.shape()[0];
        let cc = self.rt.manifest.pick_attn_bucket(c)?;
        let mut out = self.rt.execute(
            &format!("chunk_attn_b{bb}_c{cc}"),
            vec![
                pad0_f32(q, bb, 0.0),
                pad0_f32(k, cc, 0.0),
                pad0_f32(v, cc, 0.0),
                pad_pos(q_pos, bb),
                Tensor::scalar_i32(k_base),
                Tensor::scalar_i32(valid.min(c as i32)),
            ],
        )?;
        let l = out.pop().unwrap().slice0(0, b);
        let m = out.pop().unwrap().slice0(0, b);
        let o = out.pop().unwrap().slice0(0, b);
        Ok(Partials { o, m, l })
    }

    fn post(&self, attn_o: &Tensor, x: &Tensor, wo: &Tensor,
            ffn_norm: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor)
            -> Result<Tensor> {
        let b = x.shape()[0];
        let bb = self.bucket(b)?;
        let out = self.rt.execute(
            &format!("post_b{bb}"),
            vec![
                pad0_f32(attn_o, bb, 0.0),
                pad0_f32(x, bb, 0.0),
                wo.clone(),
                ffn_norm.clone(),
                w1.clone(),
                w3.clone(),
                w2.clone(),
            ],
        )?;
        Ok(out.into_iter().next().unwrap().slice0(0, b))
    }

    fn lm_head(&self, x: &Tensor, final_norm: &Tensor, w_lm: &Tensor)
               -> Result<Tensor> {
        let b = x.shape()[0];
        let bb = self.bucket(b)?;
        let out = self.rt.execute(
            &format!("lm_head_b{bb}"),
            vec![pad0_f32(x, bb, 0.0), final_norm.clone(), w_lm.clone()],
        )?;
        Ok(out.into_iter().next().unwrap().slice0(0, b))
    }

    fn router(&self, q: &Tensor, embs: &Tensor) -> Result<Tensor> {
        let b = q.shape()[0];
        let bb = self.bucket(b)?;
        let c = embs.shape()[0];
        let max_c = *self.rt.manifest.router_chunk_buckets.last().unwrap();
        // Split oversize chunk sets across multiple router calls.
        let mut pieces: Vec<Tensor> = Vec::new();
        let mut start = 0;
        while start < c {
            let end = (start + max_c).min(c);
            let cc = self.rt.manifest.pick_router_bucket(end - start)?;
            let embs_pad = pad0_f32(&embs.slice0(start, end), cc, 0.0);
            let out = self.rt.execute(
                &format!("router_b{bb}_c{cc}"),
                vec![pad0_f32(q, bb, 0.0), embs_pad],
            )?;
            let scores = out.into_iter().next().unwrap(); // [bb, cc]
            // slice rows to b, cols to (end-start)
            let mut piece = vec![0f32; b * (end - start)];
            let s = scores.as_f32();
            for bi in 0..b {
                for ci in 0..(end - start) {
                    piece[bi * (end - start) + ci] = s[bi * cc + ci];
                }
            }
            pieces.push(Tensor::f32(&[b, end - start], piece));
            start = end;
        }
        if pieces.len() == 1 {
            return Ok(pieces.pop().unwrap());
        }
        // concat along axis 1
        let total: usize = pieces.iter().map(|p| p.shape()[1]).sum();
        let mut data = vec![0f32; b * total];
        let mut off = 0;
        for p in &pieces {
            let w = p.shape()[1];
            for bi in 0..b {
                data[bi * total + off..bi * total + off + w]
                    .copy_from_slice(&p.as_f32()[bi * w..(bi + 1) * w]);
            }
            off += w;
        }
        Ok(Tensor::f32(&[b, total], data))
    }

    fn exec_plan(&self, plan: &StepPlan, x: Tensor,
                 ctx: &mut PlanExecCtx<'_>) -> Result<PlanExecOut> {
        crate::plan::exec::execute_plan(self, plan, x, ctx)
    }

    fn merge2(&self, a: &Partials, b: &Partials) -> Result<Partials> {
        let bsz = a.batch();
        let bb = self.bucket(bsz)?;
        let neg_inf = f32::NEG_INFINITY;
        let mut out = self.rt.execute(
            &format!("merge2_b{bb}"),
            vec![
                pad0_f32(&a.o, bb, 0.0),
                pad0_f32(&a.m, bb, neg_inf),
                pad0_f32(&a.l, bb, 0.0),
                pad0_f32(&b.o, bb, 0.0),
                pad0_f32(&b.m, bb, neg_inf),
                pad0_f32(&b.l, bb, 0.0),
            ],
        )?;
        let l = out.pop().unwrap().slice0(0, bsz);
        let m = out.pop().unwrap().slice0(0, bsz);
        let o = out.pop().unwrap().slice0(0, bsz);
        Ok(Partials { o, m, l })
    }
}

// ---------------------------------------------------------- NativeBackend

/// Pure-rust execution (fallback + oracle), parallel by default.
///
/// Owns the execution [`ThreadPool`] the tiled kernels fan out over and
/// the precomputed RoPE inverse-frequency table. Thread count resolves
/// via [`ThreadPool::resolve_threads`] (explicit > `MOSKA_THREADS` env >
/// machine size); `threads == 1` keeps everything on the calling thread —
/// no pool is created and every kernel takes the serial reference path —
/// and parallel execution is bit-identical to that in any case.
pub struct NativeBackend {
    model: ModelConfig,
    chunk: usize,
    pool: Option<Arc<ThreadPool>>,
    rope_freqs: Vec<f64>,
    /// Kernel-flavor vtable (see [`crate::runtime::simd`]); defaults to
    /// the process-global flavor.
    kern: &'static Kernels,
}

impl NativeBackend {
    /// Auto-sized pool (see [`ThreadPool::resolve_threads`]).
    pub fn new(model: ModelConfig, chunk: usize) -> NativeBackend {
        NativeBackend::with_threads(model, chunk, 0)
    }

    /// Explicit thread count; `0` = auto, `1` = serial (no pool).
    /// Workers are core-pinned when `MOSKA_PIN=1`
    /// ([`ThreadPool::resolve_pin`]).
    pub fn with_threads(model: ModelConfig, chunk: usize, threads: usize)
                        -> NativeBackend {
        let n = ThreadPool::resolve_threads(threads);
        let pool = if n <= 1 {
            None
        } else if ThreadPool::resolve_pin(false) {
            Some(Arc::new(ThreadPool::new_pinned(
                n,
                ThreadPool::resolve_pin_base(),
            )))
        } else {
            Some(Arc::new(ThreadPool::new(n)))
        };
        let rope_freqs =
            native::rope_inv_freq(model.head_dim, model.rope_theta);
        NativeBackend {
            model, chunk, pool, rope_freqs, kern: Kernels::global(),
        }
    }

    /// Share an existing pool (e.g. one pool across disagg node twins).
    pub fn with_pool(model: ModelConfig, chunk: usize,
                     pool: Arc<ThreadPool>) -> NativeBackend {
        let rope_freqs =
            native::rope_inv_freq(model.head_dim, model.rope_theta);
        let pool = if pool.threads() <= 1 { None } else { Some(pool) };
        NativeBackend {
            model, chunk, pool, rope_freqs, kern: Kernels::global(),
        }
    }

    /// Run this backend's math on an explicit kernel flavor (A/B
    /// benching, flavor property tests); the default is the
    /// process-global flavor.
    pub fn with_kernel(mut self, kern: &'static Kernels) -> NativeBackend {
        self.kern = kern;
        self
    }

    /// [`NativeBackend::with_kernel`] from a [`KernelSpec`].
    pub fn with_kernel_spec(self, spec: KernelSpec) -> NativeBackend {
        self.with_kernel(kernels_for(spec))
    }

    pub fn tiny() -> NativeBackend {
        NativeBackend::new(ModelConfig::tiny(), 64)
    }

    /// Kernel-level pool handle (None ⇒ serial).
    fn exec(&self) -> Option<&ThreadPool> {
        self.pool.as_deref()
    }

    /// Worker threads backing this backend (1 = serial).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.threads()).unwrap_or(1)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn model(&self) -> &ModelConfig {
        &self.model
    }

    fn chunk_size(&self) -> usize {
        self.chunk
    }

    fn max_attn_tokens(&self) -> usize {
        // native math takes any length; cap for parity with the compiled
        // buckets so coalescing behaves identically across backends
        1024
    }

    fn embed(&self, tokens: &Tensor, emb: &Tensor) -> Result<Tensor> {
        Ok(native::embed(tokens, emb))
    }

    fn qkv(&self, x: &Tensor, attn_norm: &Tensor, wq: &Tensor, wk: &Tensor,
           wv: &Tensor, pos: &[i32]) -> Result<(Tensor, Tensor, Tensor)> {
        Ok(native::qkv_exec(&self.model, x, attn_norm, wq, wk, wv, pos,
                            Some(&self.rope_freqs), self.exec(),
                            self.kern))
    }

    fn chunk_attn(&self, q: &Tensor, k: &Tensor, v: &Tensor, q_pos: &[i32],
                  k_base: i32, valid: i32) -> Result<Partials> {
        Ok(native::chunk_attn_exec_kern(q, k, v, q_pos, k_base, valid,
                                        self.exec(), self.kern))
    }

    fn post(&self, attn_o: &Tensor, x: &Tensor, wo: &Tensor,
            ffn_norm: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor)
            -> Result<Tensor> {
        Ok(native::post_exec(&self.model, attn_o, x, wo, ffn_norm, w1, w3,
                             w2, self.exec(), self.kern))
    }

    fn lm_head(&self, x: &Tensor, final_norm: &Tensor, w_lm: &Tensor)
               -> Result<Tensor> {
        Ok(native::lm_head_exec(&self.model, x, final_norm, w_lm,
                                self.exec(), self.kern))
    }

    fn router(&self, q: &Tensor, embs: &Tensor) -> Result<Tensor> {
        Ok(native::router_score_exec_kern(q, embs, self.exec(), self.kern))
    }

    fn merge2(&self, a: &Partials, b: &Partials) -> Result<Partials> {
        Ok(native::merge2(a, b))
    }

    fn exec_pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    fn kernels(&self) -> &'static Kernels {
        self.kern
    }

    fn chunk_attn_arena(&self, q: &Tensor, k: &Tensor, v: &Tensor,
                        q_pos: &[i32], k_base: i32, valid: i32,
                        arena: &mut TensorArena) -> Result<Partials> {
        let (b, h, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
        let mut out = arena.take_partials(b, h, dh);
        native::chunk_attn_exec_into_kern(q, k, v, q_pos, k_base, valid,
                                          self.exec(), self.kern,
                                          &mut out);
        Ok(out)
    }

    fn exec_plan(&self, plan: &StepPlan, x: Tensor,
                 ctx: &mut PlanExecCtx<'_>) -> Result<PlanExecOut> {
        crate::plan::exec::execute_plan(self, plan, x, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_helpers() {
        let t = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = pad0_f32(&t, 4, 9.0);
        assert_eq!(p.shape(), &[4, 3]);
        assert_eq!(p.as_f32()[6..], [9.0; 6]);
        let i = Tensor::i32(&[2], vec![5, 6]);
        let pi = pad0_i32(&i, 3, 0);
        assert_eq!(pi.as_i32(), &[5, 6, 0]);
        let pp = pad_pos(&[7], 3);
        assert_eq!(pp.as_i32(), &[7, -1, -1]);
    }

    #[test]
    fn native_backend_smoke() {
        let be = NativeBackend::tiny();
        let cfg = be.model().clone();
        let mut rng = crate::util::rng::Rng::new(0);
        let mut emb = vec![0f32; cfg.vocab * cfg.d_model];
        rng.fill_normal_f32(&mut emb);
        let emb = Tensor::f32(&[cfg.vocab, cfg.d_model], emb);
        let tokens = Tensor::i32(&[3], vec![1, 2, 3]);
        let x = be.embed(&tokens, &emb).unwrap();
        assert_eq!(x.shape(), &[3, cfg.d_model]);
    }
}
