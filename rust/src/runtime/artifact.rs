//! Artifact manifest: registry of AOT-compiled HLO artifacts.
//!
//! `artifacts/manifest.json` is written by `python/compile/aot.py` and
//! enumerates every lowered (op, batch-bucket) with its input/output
//! shapes. The runtime validates call shapes against it, and the bucket
//! picker uses it to find the smallest compiled batch ≥ the live batch.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::tensor::{DType, Tensor};
use crate::util::json::Json;

/// One declared tensor port (input or output) of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct Port {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

/// Metadata for one compiled artifact (one HLO file).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    /// Path relative to the artifacts dir.
    pub file: String,
    pub inputs: Vec<Port>,
    pub outputs: Vec<Port>,
}

impl ArtifactMeta {
    /// Validate concrete tensors against the declared input ports.
    pub fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.inputs.len() {
            bail!("{}: expected {} inputs, got {}",
                  self.name, self.inputs.len(), inputs.len());
        }
        for (t, p) in inputs.iter().zip(&self.inputs) {
            if t.dtype() != p.dtype || t.shape() != p.shape.as_slice() {
                bail!(
                    "{}: input '{}' expects {}{:?}, got {}{:?}",
                    self.name, p.name, p.dtype, p.shape, t.dtype(), t.shape()
                );
            }
        }
        Ok(())
    }
}

/// A shared-domain KV store declared in the manifest.
#[derive(Debug, Clone)]
pub struct DomainMeta {
    pub name: String,
    pub tokens: usize,
    pub chunks: usize,
    pub file: String,
}

/// The parsed artifact registry.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelConfig,
    /// Tokens per KV chunk (the Shared-KV Attention granule).
    pub chunk: usize,
    pub batch_buckets: Vec<usize>,
    pub router_chunk_buckets: Vec<usize>,
    /// Compiled chunk_attn K/V token lengths (run coalescing targets).
    pub attn_token_buckets: Vec<usize>,
    pub weights_file: String,
    pub domains: Vec<DomainMeta>,
    artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = Path::new(dir).join("manifest.json");
        let j = Json::read_file(path.to_str().context("utf8 path")?)
            .with_context(|| format!("loading manifest from {dir} — did you run `make artifacts`?"))?;
        let model = ModelConfig::from_json(j.get("model")?)?;
        let chunk = j.get("chunk")?.as_usize()?;
        let batch_buckets = j.get("batch_buckets")?.as_usize_vec()?;
        let router_chunk_buckets =
            j.get("router_chunk_buckets")?.as_usize_vec()?;
        // older manifests (pre §Perf opt 2) lack attn buckets
        let attn_token_buckets = match j.opt("attn_token_buckets") {
            Some(v) => v.as_usize_vec()?,
            None => vec![chunk],
        };
        let weights_file = j.get("weights")?.as_str()?.to_string();

        let mut domains = Vec::new();
        for d in j.get("domains")?.as_arr()? {
            domains.push(DomainMeta {
                name: d.get("name")?.as_str()?.to_string(),
                tokens: d.get("tokens")?.as_usize()?,
                chunks: d.get("chunks")?.as_usize()?,
                file: d.get("file")?.as_str()?.to_string(),
            });
        }

        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts")?.as_arr()? {
            let name = a.get("name")?.as_str()?.to_string();
            let parse_ports = |key: &str| -> Result<Vec<Port>> {
                a.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|p| {
                        Ok(Port {
                            name: p
                                .opt("name")
                                .map(|n| n.as_str().map(str::to_string))
                                .transpose()?
                                .unwrap_or_default(),
                            dtype: DType::from_str(p.get("dtype")?.as_str()?)
                                .context("bad dtype")?,
                            shape: p.get("shape")?.as_usize_vec()?,
                        })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name,
                    file: a.get("file")?.as_str()?.to_string(),
                    inputs: parse_ports("inputs")?,
                    outputs: parse_ports("outputs")?,
                },
            );
        }

        // sanity: buckets sorted ascending (bucket picking relies on it)
        let mut sorted = batch_buckets.clone();
        sorted.sort_unstable();
        if sorted != batch_buckets || batch_buckets.is_empty() {
            bail!("batch_buckets must be non-empty ascending: {batch_buckets:?}");
        }

        Ok(Manifest {
            dir: PathBuf::from(dir),
            model,
            chunk,
            batch_buckets,
            router_chunk_buckets,
            attn_token_buckets,
            weights_file,
            domains,
            artifacts,
        })
    }

    /// Smallest compiled chunk_attn token bucket ≥ `t`.
    pub fn pick_attn_bucket(&self, t: usize) -> Result<usize> {
        self.attn_token_buckets
            .iter()
            .copied()
            .find(|&x| x >= t)
            .with_context(|| {
                format!("K/V length {t} exceeds largest attn bucket {:?}",
                        self.attn_token_buckets.last())
            })
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn artifact_names(&self) -> impl Iterator<Item = &String> {
        self.artifacts.keys()
    }

    pub fn artifact_count(&self) -> usize {
        self.artifacts.len()
    }

    /// Smallest compiled batch bucket ≥ `b`.
    pub fn pick_batch_bucket(&self, b: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .find(|&x| x >= b)
            .with_context(|| {
                format!("batch {b} exceeds largest bucket {:?}",
                        self.batch_buckets.last())
            })
    }

    /// Smallest compiled router chunk-count bucket ≥ `c`.
    pub fn pick_router_bucket(&self, c: usize) -> Result<usize> {
        self.router_chunk_buckets
            .iter()
            .copied()
            .find(|&x| x >= c)
            .with_context(|| {
                format!("chunk count {c} exceeds largest router bucket {:?}",
                        self.router_chunk_buckets.last())
            })
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights_file)
    }

    pub fn domain_path(&self, d: &DomainMeta) -> PathBuf {
        self.dir.join(&d.file)
    }
}

/// Resolve the artifacts directory from a CLI `--artifacts` option:
/// an explicit non-empty value wins, otherwise [`default_artifacts_dir`]
/// (one place to change discovery for every launcher subcommand).
pub fn resolve_artifacts_dir(args: &crate::util::cli::Args) -> String {
    match args.get("artifacts") {
        Some("") | None => default_artifacts_dir(),
        Some(d) => d.to_string(),
    }
}

/// Default artifacts directory (repo root), overridable via env.
pub fn default_artifacts_dir() -> String {
    std::env::var("MOSKA_ARTIFACTS").unwrap_or_else(|_| {
        // examples/tests run from the repo root; benches sometimes from
        // target/ — walk up until we find a manifest.
        for base in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(base).join("manifest.json").exists() {
                return base.to_string();
            }
        }
        "artifacts".to_string()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_picking() {
        let man = Manifest {
            dir: PathBuf::from("x"),
            model: ModelConfig::tiny(),
            chunk: 64,
            batch_buckets: vec![1, 2, 4, 8, 16, 32],
            router_chunk_buckets: vec![16, 64, 256],
            attn_token_buckets: vec![64, 256, 1024],
            weights_file: String::new(),
            domains: vec![],
            artifacts: BTreeMap::new(),
        };
        assert_eq!(man.pick_batch_bucket(1).unwrap(), 1);
        assert_eq!(man.pick_batch_bucket(3).unwrap(), 4);
        assert_eq!(man.pick_batch_bucket(32).unwrap(), 32);
        assert!(man.pick_batch_bucket(33).is_err());
        assert_eq!(man.pick_router_bucket(17).unwrap(), 64);
    }

    #[test]
    fn check_inputs_validates() {
        let meta = ArtifactMeta {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            inputs: vec![Port {
                name: "x".into(),
                dtype: DType::F32,
                shape: vec![2, 3],
            }],
            outputs: vec![],
        };
        assert!(meta
            .check_inputs(&[Tensor::zeros_f32(&[2, 3])])
            .is_ok());
        assert!(meta
            .check_inputs(&[Tensor::zeros_f32(&[3, 2])])
            .is_err());
        assert!(meta.check_inputs(&[Tensor::zeros_i32(&[2, 3])]).is_err());
        assert!(meta.check_inputs(&[]).is_err());
    }
}
