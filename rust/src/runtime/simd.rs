//! SIMD microkernel layer: vectorized inner loops for the decode hot
//! path, behind a runtime-dispatched [`Kernels`] vtable.
//!
//! The parallel execution layer (PR 1) tiles work across cores, but each
//! tile ran the seed scalar loops — a sequential f32 reduction per dot
//! product and one multiply-add per cycle at best. This module supplies
//! three interchangeable kernel *flavors* for the five primitive inner
//! ops everything hot routes through (`mm_rows`/`mm_cols` column
//! updates, the `chunk_attn_rows` per-row body, `router_cells` score
//! cells, and the `merge2_row_into`/`finalize_into` tails):
//!
//! * **`scalar`** — the seed kernels, bit-for-bit: plain multiply-then-
//!   add, sequential `k`-ascending reductions. The reference every
//!   golden/replay artifact was produced with (`MOSKA_KERNEL=scalar`).
//! * **`lanes8`** — the portable 8-lane flavor: a fixed-width
//!   lane-striped accumulator (`lanes[i % 8]`) with fused multiply-add
//!   (`f32::mul_add`) and the pinned [`reduce8`] tree. Pure safe Rust;
//!   the fallback on hardware without vector units, and the oracle the
//!   arch-specific flavors are property-tested against.
//! * **`avx2`** / **`neon`** — `std::arch` intrinsics (x86-64 AVX2+FMA,
//!   aarch64 NEON), selected once at startup by runtime feature
//!   detection. Same lane striping, same tail handling, same scalar
//!   [`reduce8`] — **bit-identical to `lanes8` on every input**.
//!
//! ## Determinism contract
//!
//! The seed contract ("`k` ascends per output element") pinned a purely
//! sequential reduction order, which no vector unit can honor. The SIMD
//! flavors replace it with an equally strict one:
//!
//! * **Reductions** (QK^T dots, router scores) accumulate into a fixed
//!   8-lane stripe — element `i` always lands in lane `i % 8`,
//!   regardless of vector width — and collapse through the pinned
//!   [`reduce8`] tree `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` in scalar
//!   f32 arithmetic. Ragged tails feed lanes `0..n%8` with scalar
//!   `mul_add`, identically in every flavor.
//! * **Element-wise updates** (matmul column updates, the V
//!   accumulation, merge/finalize tails) keep their per-element order;
//!   each element is one fused multiply-add (or IEEE division), which
//!   rounds identically everywhere.
//!
//! Every flavor still satisfies the parallel-execution contract from
//! PR 1 — tiles own disjoint output regions and run the same per-element
//! order as their serial counterpart — so within a flavor, output is
//! bit-identical across thread counts; and across the three SIMD
//! flavors, output is bit-identical, period (asserted by
//! `tests/prop_kernels.rs` and the in-module tests). `scalar` differs
//! from the SIMD flavors in low-order bits (different reduction order,
//! no fusion) but decodes the same tokens — `scripts/ci.sh` runs the
//! tier-1 suite and a synthetic disagg token comparison under both.
//!
//! ## Dispatch
//!
//! [`Kernels::global()`] resolves once per process from the
//! `MOSKA_KERNEL` env var (`scalar | simd | lanes8`, default auto =
//! best available), and [`set_global_spec`] lets the launcher pin it
//! from `--kernel` / `serving.kernel` config. Each
//! [`NativeBackend`][crate::runtime::NativeBackend] holds a `&'static
//! Kernels` (defaulting to the global) so tests and benches can A/B
//! flavors side by side in one process.

use std::sync::OnceLock;

use anyhow::{bail, Result};

// ---------------------------------------------------------------- flavors

/// Which kernel flavor to run (CLI `--kernel`, `serving.kernel`,
/// `MOSKA_KERNEL`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelSpec {
    /// Best available: AVX2+FMA > NEON > `lanes8`.
    #[default]
    Auto,
    /// The seed scalar kernels (pre-SIMD bit behavior).
    Scalar,
    /// Explicitly the vectorized path (same resolution as `Auto`).
    Simd,
    /// The portable 8-lane flavor, even when AVX2/NEON is available
    /// (property-test oracle, A/B baseline).
    Lanes8,
}

impl KernelSpec {
    pub fn parse(s: &str) -> Result<KernelSpec> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Ok(KernelSpec::Auto),
            "scalar" | "seed" => Ok(KernelSpec::Scalar),
            "simd" => Ok(KernelSpec::Simd),
            "lanes8" | "fallback" => Ok(KernelSpec::Lanes8),
            other => bail!(
                "unknown kernel flavor '{other}' (auto|simd|scalar|lanes8)"
            ),
        }
    }
}

/// Arguments for one query-row of chunk attention (see
/// [`Kernels::attn_row`]): `ks`/`vs` are the chunk-major `[C, Hkv, dh]`
/// K/V payloads, `kv` the GQA KV head this query head reads, `vis` the
/// causally visible key count (> 0).
pub struct AttnRowArgs<'a> {
    pub qrow: &'a [f32],
    pub ks: &'a [f32],
    pub vs: &'a [f32],
    pub kv: usize,
    pub hkv: usize,
    pub dh: usize,
    pub vis: usize,
    pub scale: f32,
}

type FmaRowFn = fn(&mut [f32], &[f32], f32);
type AttnRowFn = for<'a> fn(&AttnRowArgs<'a>, &mut [f32], &mut [f32])
                            -> (f32, f32);
type RouterCellFn = fn(&[f32], &[f32], usize, usize, usize) -> f32;
type Scale2AddFn = fn(&mut [f32], f32, &[f32], f32);
type DivRowFn = fn(&mut [f32], &[f32], f32);

/// One kernel flavor: the five primitive inner ops the hot loops in
/// [`native`][crate::runtime::native] dispatch through. Selected once
/// (per process via [`Kernels::global`], per backend via
/// [`NativeBackend::with_kernel`][crate::runtime::NativeBackend::with_kernel]);
/// the fn pointers are called per row/column-strip, so dispatch cost is
/// amortized over `dh`..`n` elements of work.
pub struct Kernels {
    pub name: &'static str,
    fma_row_fn: FmaRowFn,
    attn_row_fn: AttnRowFn,
    router_cell_fn: RouterCellFn,
    scale2_add_fn: Scale2AddFn,
    div_row_fn: DivRowFn,
}

impl Kernels {
    /// `orow[j] += xv * wrow[j]` — the matmul column update (and the
    /// attention V accumulation, which is the same op).
    #[inline]
    pub fn fma_row(&self, orow: &mut [f32], wrow: &[f32], xv: f32) {
        (self.fma_row_fn)(orow, wrow, xv)
    }

    /// One query-row chunk-attention body: QK^T scores into
    /// `scores[..vis]`, online-softmax probabilities, V accumulation
    /// into `orow` (must arrive zeroed). Returns `(m, l)`.
    #[inline]
    pub fn attn_row(&self, args: &AttnRowArgs<'_>, scores: &mut [f32],
                    orow: &mut [f32]) -> (f32, f32) {
        (self.attn_row_fn)(args, scores, orow)
    }

    /// One router score cell: mean over `h` query heads of `q_h ·
    /// emb_{kv(h)}`; `qrow` is the row's `[h, dh]` block, `erow` the
    /// chunk's `[hkv, dh]` embedding block.
    #[inline]
    pub fn router_cell(&self, qrow: &[f32], erow: &[f32], h: usize,
                       dh: usize, group: usize) -> f32 {
        (self.router_cell_fn)(qrow, erow, h, dh, group)
    }

    /// `dst[j] = dst[j] * s1 + src[j] * s2` — the LSE-merge o-row tail.
    #[inline]
    pub fn scale2_add(&self, dst: &mut [f32], s1: f32, src: &[f32],
                      s2: f32) {
        (self.scale2_add_fn)(dst, s1, src, s2)
    }

    /// `dst[j] = src[j] / l` — the finalize normalization tail.
    #[inline]
    pub fn div_row(&self, dst: &mut [f32], src: &[f32], l: f32) {
        (self.div_row_fn)(dst, src, l)
    }

    /// The process-wide flavor: `MOSKA_KERNEL` env (or what
    /// [`set_global_spec`] pinned first), default auto-detect. Resolved
    /// once; every free-function kernel wrapper and every backend built
    /// without an explicit flavor uses this.
    pub fn global() -> &'static Kernels {
        *GLOBAL.get_or_init(|| {
            let spec = match std::env::var("MOSKA_KERNEL") {
                Ok(s) => match KernelSpec::parse(&s) {
                    Ok(spec) => spec,
                    Err(e) => panic!("MOSKA_KERNEL: {e}"),
                },
                Err(_) => KernelSpec::Auto,
            };
            // resolve_explicit, NOT kernels_for: `Auto` maps back to
            // this global, which would re-enter the OnceLock init
            resolve_explicit(spec)
        })
    }
}

static GLOBAL: OnceLock<&'static Kernels> = OnceLock::new();

/// Pin the process-wide flavor from launcher config (`--kernel`,
/// `serving.kernel`). Conflicts are rejected loudly and
/// deterministically — a set `MOSKA_KERNEL` env that disagrees with the
/// requested flavor errors here regardless of whether anything resolved
/// [`Kernels::global`] earlier, and so does a second conflicting pin —
/// so an A/B misconfiguration can never silently mix flavors.
pub fn set_global_spec(spec: KernelSpec) -> Result<()> {
    let want = kernels_for(spec);
    if let Ok(s) = std::env::var("MOSKA_KERNEL") {
        let env_spec = KernelSpec::parse(&s)?;
        if env_spec != KernelSpec::Auto {
            anyhow::ensure!(
                std::ptr::eq(kernels_for(env_spec), want),
                "MOSKA_KERNEL={} conflicts with the requested kernel \
                 flavor '{}' — drop one of the two",
                s.trim(), want.name,
            );
        }
    }
    let got = GLOBAL.get_or_init(|| want);
    anyhow::ensure!(
        std::ptr::eq(*got, want),
        "kernel flavor already pinned to '{}' (requested '{}')",
        got.name, want.name,
    );
    Ok(())
}

/// Resolve a flavor spec to its vtable. `Auto` means "no explicit
/// request" and follows the process-global flavor (so `MOSKA_KERNEL`
/// keeps working when a launcher passes its `--kernel` default
/// through); `Simd` explicitly picks the best runtime-detected flavor.
pub fn kernels_for(spec: KernelSpec) -> &'static Kernels {
    match spec {
        KernelSpec::Auto => Kernels::global(),
        explicit => resolve_explicit(explicit),
    }
}

/// [`kernels_for`] minus the `Auto` → global indirection (`Auto` here
/// means auto-*detect*): what the global's own initializer and every
/// explicit spec resolve through.
fn resolve_explicit(spec: KernelSpec) -> &'static Kernels {
    match spec {
        KernelSpec::Scalar => &SCALAR,
        KernelSpec::Lanes8 => &LANES8,
        KernelSpec::Auto | KernelSpec::Simd => best_simd(),
    }
}

#[cfg(target_arch = "x86_64")]
fn best_simd() -> &'static Kernels {
    if std::arch::is_x86_feature_detected!("avx2")
        && std::arch::is_x86_feature_detected!("fma")
    {
        &AVX2
    } else {
        &LANES8
    }
}

#[cfg(target_arch = "aarch64")]
fn best_simd() -> &'static Kernels {
    &NEON
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn best_simd() -> &'static Kernels {
    &LANES8
}

static SCALAR: Kernels = Kernels {
    name: "scalar",
    fma_row_fn: scalar::fma_row,
    attn_row_fn: scalar::attn_row,
    router_cell_fn: scalar::router_cell,
    scale2_add_fn: scalar::scale2_add,
    div_row_fn: scalar::div_row,
};

static LANES8: Kernels = Kernels {
    name: "lanes8",
    fma_row_fn: lanes8::fma_row,
    attn_row_fn: lanes8::attn_row,
    router_cell_fn: lanes8::router_cell,
    scale2_add_fn: lanes8::scale2_add,
    div_row_fn: scalar::div_row, // IEEE division: identical in any order
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    name: "avx2",
    fma_row_fn: avx2_fma_row,
    attn_row_fn: avx2_attn_row,
    router_cell_fn: avx2_router_cell,
    scale2_add_fn: avx2_scale2_add,
    div_row_fn: scalar::div_row,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    name: "neon",
    fma_row_fn: neon_fma_row,
    attn_row_fn: neon_attn_row,
    router_cell_fn: neon_router_cell,
    scale2_add_fn: neon_scale2_add,
    div_row_fn: scalar::div_row,
};

// ------------------------------------------------------- shared helpers

/// The pinned lane-reduction tree every SIMD flavor collapses its
/// 8-lane accumulator through, in scalar f32 arithmetic: pairwise over
/// a vector-width-agnostic pattern (`l0+l4` is what splitting a 256-bit
/// register into 128-bit halves produces naturally; NEON's two 4-lane
/// accumulators and the portable array reduce the same way).
#[inline(always)]
fn reduce8(l: &[f32; 8]) -> f32 {
    let s0 = l[0] + l[4];
    let s1 = l[1] + l[5];
    let s2 = l[2] + l[6];
    let s3 = l[3] + l[7];
    (s0 + s2) + (s1 + s3)
}

/// Ragged-tail accumulation shared by every SIMD flavor: elements
/// `[i0, n)` land in lanes `0..n-i0` with scalar fused multiply-add —
/// the same ops in the same order whether the main loop ran on AVX2,
/// NEON, or the portable stripe.
#[inline(always)]
fn dot_tail(lanes: &mut [f32; 8], a: &[f32], b: &[f32], i0: usize,
            n: usize) {
    let mut t = 0;
    let mut i = i0;
    while i < n {
        lanes[t] = a[i].mul_add(b[i], lanes[t]);
        t += 1;
        i += 1;
    }
}

// ------------------------------------------------------- scalar (seed)

/// The seed kernels, arithmetic preserved bit-for-bit: multiply *then*
/// add (no fusion), sequential reductions. `MOSKA_KERNEL=scalar`
/// reproduces pre-SIMD output exactly (regression-tested against
/// inline references in `tests/prop_kernels.rs`).
mod scalar {
    use super::AttnRowArgs;

    pub fn fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
        for (o, &wv) in orow.iter_mut().zip(wrow) {
            *o += xv * wv;
        }
    }

    pub fn attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                    orow: &mut [f32]) -> (f32, f32) {
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            let krow = &a.ks[base..base + dh];
            let dot: f32 =
                a.qrow.iter().zip(krow).map(|(x, y)| x * y).sum();
            let s = dot * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        for j in 0..a.vis {
            let p = (scores[j] - mx).exp();
            li += p;
            let base = (j * hkv + kv) * dh;
            let vrow = &a.vs[base..base + dh];
            for (oo, &vv) in orow.iter_mut().zip(vrow) {
                *oo += p * vv;
            }
        }
        (mx, li)
    }

    pub fn router_cell(qrow: &[f32], erow: &[f32], h: usize, dh: usize,
                       group: usize) -> f32 {
        let mut acc = 0f32;
        for hi in 0..h {
            let kv = hi / group;
            let q = &qrow[hi * dh..(hi + 1) * dh];
            let e = &erow[kv * dh..(kv + 1) * dh];
            acc += q.iter().zip(e).map(|(x, y)| x * y).sum::<f32>();
        }
        acc / h as f32
    }

    pub fn scale2_add(dst: &mut [f32], s1: f32, src: &[f32], s2: f32) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = *d * s1 + s * s2;
        }
    }

    pub fn div_row(dst: &mut [f32], src: &[f32], l: f32) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s / l;
        }
    }
}

// ---------------------------------------------------- lanes8 (portable)

/// The portable 8-lane flavor: defines the SIMD semantics in safe Rust.
/// `f32::mul_add` is the IEEE fused op (identical to AVX2 `vfmadd` /
/// NEON `fmla` bit-for-bit); the stripe + [`super::reduce8`] pin the
/// reduction order the vector flavors reproduce.
mod lanes8 {
    use super::{dot_tail, reduce8, AttnRowArgs};

    #[inline(always)]
    pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut lanes = [0f32; 8];
        let mut i = 0;
        while i + 8 <= n {
            for j in 0..8 {
                lanes[j] = a[i + j].mul_add(b[i + j], lanes[j]);
            }
            i += 8;
        }
        dot_tail(&mut lanes, a, b, i, n);
        reduce8(&lanes)
    }

    pub fn fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
        for (o, &wv) in orow.iter_mut().zip(wrow) {
            *o = wv.mul_add(xv, *o);
        }
    }

    pub fn attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                    orow: &mut [f32]) -> (f32, f32) {
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            let s = dot8(a.qrow, &a.ks[base..base + dh]) * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        for j in 0..a.vis {
            let p = (scores[j] - mx).exp();
            li += p;
            let base = (j * hkv + kv) * dh;
            fma_row(orow, &a.vs[base..base + dh], p);
        }
        (mx, li)
    }

    pub fn router_cell(qrow: &[f32], erow: &[f32], h: usize, dh: usize,
                       group: usize) -> f32 {
        let mut acc = 0f32;
        for hi in 0..h {
            let kv = hi / group;
            acc += dot8(&qrow[hi * dh..(hi + 1) * dh],
                        &erow[kv * dh..(kv + 1) * dh]);
        }
        acc / h as f32
    }

    pub fn scale2_add(dst: &mut [f32], s1: f32, src: &[f32], s2: f32) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s.mul_add(s2, *d * s1);
        }
    }
}

// -------------------------------------------------------- avx2 (x86-64)

/// AVX2+FMA implementations. Every `unsafe fn` here requires AVX2 and
/// FMA support; the safe wrappers below are only reachable through the
/// [`AVX2`] table, which [`best_simd`] constructs exclusively behind
/// `is_x86_feature_detected!` — that detection is the safety proof for
/// every call site.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::{dot_tail, reduce8, AttnRowArgs};

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot8(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut lanes = [0f32; 8];
        let mut i = 0;
        unsafe {
            let mut acc = _mm256_setzero_ps();
            while i + 8 <= n {
                let av = _mm256_loadu_ps(a.as_ptr().add(i));
                let bv = _mm256_loadu_ps(b.as_ptr().add(i));
                acc = _mm256_fmadd_ps(av, bv, acc);
                i += 8;
            }
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        }
        dot_tail(&mut lanes, a, b, i, n);
        reduce8(&lanes)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
        let n = orow.len().min(wrow.len());
        let mut i = 0;
        unsafe {
            let xvv = _mm256_set1_ps(xv);
            // 4x unrolled: same per-element fused op, better ILP
            while i + 32 <= n {
                for u in [0usize, 8, 16, 24] {
                    let o = _mm256_loadu_ps(orow.as_ptr().add(i + u));
                    let w = _mm256_loadu_ps(wrow.as_ptr().add(i + u));
                    _mm256_storeu_ps(orow.as_mut_ptr().add(i + u),
                                     _mm256_fmadd_ps(w, xvv, o));
                }
                i += 32;
            }
            while i + 8 <= n {
                let o = _mm256_loadu_ps(orow.as_ptr().add(i));
                let w = _mm256_loadu_ps(wrow.as_ptr().add(i));
                _mm256_storeu_ps(orow.as_mut_ptr().add(i),
                                 _mm256_fmadd_ps(w, xvv, o));
                i += 8;
            }
        }
        while i < n {
            orow[i] = wrow[i].mul_add(xv, orow[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                           orow: &mut [f32]) -> (f32, f32) {
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            let s = unsafe { dot8(a.qrow, &a.ks[base..base + dh]) }
                * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        for j in 0..a.vis {
            let p = (scores[j] - mx).exp();
            li += p;
            let base = (j * hkv + kv) * dh;
            unsafe { fma_row(orow, &a.vs[base..base + dh], p) };
        }
        (mx, li)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn router_cell(qrow: &[f32], erow: &[f32], h: usize,
                              dh: usize, group: usize) -> f32 {
        let mut acc = 0f32;
        for hi in 0..h {
            let kv = hi / group;
            acc += unsafe {
                dot8(&qrow[hi * dh..(hi + 1) * dh],
                     &erow[kv * dh..(kv + 1) * dh])
            };
        }
        acc / h as f32
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale2_add(dst: &mut [f32], s1: f32, src: &[f32],
                             s2: f32) {
        let n = dst.len().min(src.len());
        let mut i = 0;
        unsafe {
            let s1v = _mm256_set1_ps(s1);
            let s2v = _mm256_set1_ps(s2);
            while i + 8 <= n {
                let d = _mm256_loadu_ps(dst.as_ptr().add(i));
                let s = _mm256_loadu_ps(src.as_ptr().add(i));
                let r = _mm256_fmadd_ps(s, s2v, _mm256_mul_ps(d, s1v));
                _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
                i += 8;
            }
        }
        while i < n {
            dst[i] = src[i].mul_add(s2, dst[i] * s1);
            i += 1;
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
    // SAFETY: the AVX2 table is only selectable after feature detection.
    unsafe { avx2::fma_row(orow, wrow, xv) }
}

#[cfg(target_arch = "x86_64")]
fn avx2_attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                 orow: &mut [f32]) -> (f32, f32) {
    // SAFETY: as above.
    unsafe { avx2::attn_row(a, scores, orow) }
}

#[cfg(target_arch = "x86_64")]
fn avx2_router_cell(qrow: &[f32], erow: &[f32], h: usize, dh: usize,
                    group: usize) -> f32 {
    // SAFETY: as above.
    unsafe { avx2::router_cell(qrow, erow, h, dh, group) }
}

#[cfg(target_arch = "x86_64")]
fn avx2_scale2_add(dst: &mut [f32], s1: f32, src: &[f32], s2: f32) {
    // SAFETY: as above.
    unsafe { avx2::scale2_add(dst, s1, src, s2) }
}

// ------------------------------------------------------- neon (aarch64)

/// NEON implementations (two 4-lane accumulators = the same 8-lane
/// stripe). NEON is part of the aarch64 baseline, so detection cannot
/// fail; the `target_feature` + safe-wrapper structure mirrors AVX2 for
/// uniformity (and for toolchains predating safe target-feature calls).
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    use super::{dot_tail, reduce8, AttnRowArgs};

    #[target_feature(enable = "neon")]
    pub unsafe fn dot8(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut lanes = [0f32; 8];
        let mut i = 0;
        unsafe {
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            while i + 8 <= n {
                let a0 = vld1q_f32(a.as_ptr().add(i));
                let b0 = vld1q_f32(b.as_ptr().add(i));
                let a1 = vld1q_f32(a.as_ptr().add(i + 4));
                let b1 = vld1q_f32(b.as_ptr().add(i + 4));
                acc0 = vfmaq_f32(acc0, a0, b0);
                acc1 = vfmaq_f32(acc1, a1, b1);
                i += 8;
            }
            vst1q_f32(lanes.as_mut_ptr(), acc0);
            vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        }
        dot_tail(&mut lanes, a, b, i, n);
        reduce8(&lanes)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
        let n = orow.len().min(wrow.len());
        let mut i = 0;
        unsafe {
            let xvv = vdupq_n_f32(xv);
            while i + 8 <= n {
                let o0 = vld1q_f32(orow.as_ptr().add(i));
                let w0 = vld1q_f32(wrow.as_ptr().add(i));
                let o1 = vld1q_f32(orow.as_ptr().add(i + 4));
                let w1 = vld1q_f32(wrow.as_ptr().add(i + 4));
                vst1q_f32(orow.as_mut_ptr().add(i),
                          vfmaq_f32(o0, w0, xvv));
                vst1q_f32(orow.as_mut_ptr().add(i + 4),
                          vfmaq_f32(o1, w1, xvv));
                i += 8;
            }
            while i + 4 <= n {
                let o = vld1q_f32(orow.as_ptr().add(i));
                let w = vld1q_f32(wrow.as_ptr().add(i));
                vst1q_f32(orow.as_mut_ptr().add(i),
                          vfmaq_f32(o, w, xvv));
                i += 4;
            }
        }
        while i < n {
            orow[i] = wrow[i].mul_add(xv, orow[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                           orow: &mut [f32]) -> (f32, f32) {
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            let s = unsafe { dot8(a.qrow, &a.ks[base..base + dh]) }
                * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        for j in 0..a.vis {
            let p = (scores[j] - mx).exp();
            li += p;
            let base = (j * hkv + kv) * dh;
            unsafe { fma_row(orow, &a.vs[base..base + dh], p) };
        }
        (mx, li)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn router_cell(qrow: &[f32], erow: &[f32], h: usize,
                              dh: usize, group: usize) -> f32 {
        let mut acc = 0f32;
        for hi in 0..h {
            let kv = hi / group;
            acc += unsafe {
                dot8(&qrow[hi * dh..(hi + 1) * dh],
                     &erow[kv * dh..(kv + 1) * dh])
            };
        }
        acc / h as f32
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale2_add(dst: &mut [f32], s1: f32, src: &[f32],
                             s2: f32) {
        let n = dst.len().min(src.len());
        let mut i = 0;
        unsafe {
            let s1v = vdupq_n_f32(s1);
            let s2v = vdupq_n_f32(s2);
            while i + 4 <= n {
                let d = vld1q_f32(dst.as_ptr().add(i));
                let s = vld1q_f32(src.as_ptr().add(i));
                let r = vfmaq_f32(vmulq_f32(d, s1v), s, s2v);
                vst1q_f32(dst.as_mut_ptr().add(i), r);
                i += 4;
            }
        }
        while i < n {
            dst[i] = src[i].mul_add(s2, dst[i] * s1);
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
fn neon_fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
    // SAFETY: NEON is mandatory in the aarch64 baseline.
    unsafe { neon::fma_row(orow, wrow, xv) }
}

#[cfg(target_arch = "aarch64")]
fn neon_attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                 orow: &mut [f32]) -> (f32, f32) {
    // SAFETY: as above.
    unsafe { neon::attn_row(a, scores, orow) }
}

#[cfg(target_arch = "aarch64")]
fn neon_router_cell(qrow: &[f32], erow: &[f32], h: usize, dh: usize,
                    group: usize) -> f32 {
    // SAFETY: as above.
    unsafe { neon::router_cell(qrow, erow, h, dh, group) }
}

#[cfg(target_arch = "aarch64")]
fn neon_scale2_add(dst: &mut [f32], s1: f32, src: &[f32], s2: f32) {
    // SAFETY: as above.
    unsafe { neon::scale2_add(dst, s1, src, s2) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn spec_parses() {
        assert_eq!(KernelSpec::parse("auto").unwrap(), KernelSpec::Auto);
        assert_eq!(KernelSpec::parse("").unwrap(), KernelSpec::Auto);
        assert_eq!(KernelSpec::parse("SIMD").unwrap(), KernelSpec::Simd);
        assert_eq!(KernelSpec::parse("scalar").unwrap(),
                   KernelSpec::Scalar);
        assert_eq!(KernelSpec::parse("lanes8").unwrap(),
                   KernelSpec::Lanes8);
        assert!(KernelSpec::parse("sse9").is_err());
    }

    #[test]
    fn flavor_tables_resolve() {
        assert_eq!(kernels_for(KernelSpec::Scalar).name, "scalar");
        assert_eq!(kernels_for(KernelSpec::Lanes8).name, "lanes8");
        // Simd = explicit best-detected flavor, independent of env
        let best = kernels_for(KernelSpec::Simd);
        assert!(["avx2", "neon", "lanes8"].contains(&best.name));
        // Auto follows the process-global flavor (MOSKA_KERNEL aware),
        // so the ci.sh A/B stages reach the backends through it
        assert!(std::ptr::eq(kernels_for(KernelSpec::Auto),
                             Kernels::global()));
    }

    #[test]
    fn reduce8_order_is_pinned() {
        // values where reduction order changes the f32 result: the
        // pinned tree must give exactly ((l0+l4)+(l2+l6))+((l1+l5)+(l3+l7))
        let l = [1.0e8f32, 1.0, -1.0e8, 3.0, 0.25, -7.0, 2.5e7, 11.0];
        let s0 = l[0] + l[4];
        let s1 = l[1] + l[5];
        let s2 = l[2] + l[6];
        let s3 = l[3] + l[7];
        let want = (s0 + s2) + (s1 + s3);
        assert_eq!(reduce8(&l), want);
    }

    /// The core contract: the best-detected flavor is bit-identical to
    /// the portable `lanes8` flavor on every primitive, across ragged
    /// lengths (tails of every residue mod 8).
    #[test]
    fn simd_flavors_bit_identical_to_lanes8() {
        let a = kernels_for(KernelSpec::Lanes8);
        let b = kernels_for(KernelSpec::Simd); // may be avx2/neon/lanes8
        let mut rng = Rng::new(0x51D);
        for len in [1usize, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100] {
            let mut x = vec![0f32; len];
            let mut y = vec![0f32; len];
            rng.fill_normal_f32(&mut x);
            rng.fill_normal_f32(&mut y);

            // fma_row
            let mut oa = x.clone();
            let mut ob = x.clone();
            a.fma_row(&mut oa, &y, 0.37);
            b.fma_row(&mut ob, &y, 0.37);
            assert_eq!(oa, ob, "fma_row len={len} flavor={}", b.name);

            // scale2_add
            let mut da = x.clone();
            let mut db = x.clone();
            a.scale2_add(&mut da, 0.9, &y, 1.7);
            b.scale2_add(&mut db, 0.9, &y, 1.7);
            assert_eq!(da, db, "scale2_add len={len}");

            // div_row
            let mut va = vec![0f32; len];
            let mut vb = vec![0f32; len];
            a.div_row(&mut va, &x, 3.1);
            b.div_row(&mut vb, &x, 3.1);
            assert_eq!(va, vb, "div_row len={len}");
        }

        // attn_row + router_cell over ragged dh and vis
        for &(hkv, dh, c) in
            &[(2usize, 12usize, 5usize), (2, 16, 64), (1, 33, 7)]
        {
            let mut q = vec![0f32; dh];
            let mut ks = vec![0f32; c * hkv * dh];
            let mut vs = vec![0f32; c * hkv * dh];
            rng.fill_normal_f32(&mut q);
            rng.fill_normal_f32(&mut ks);
            rng.fill_normal_f32(&mut vs);
            for vis in [1usize, c / 2 + 1, c] {
                let args = AttnRowArgs {
                    qrow: &q,
                    ks: &ks,
                    vs: &vs,
                    kv: hkv - 1,
                    hkv,
                    dh,
                    vis,
                    scale: 1.0 / (dh as f32).sqrt(),
                };
                let mut sa = vec![0f32; c];
                let mut sb = vec![0f32; c];
                let mut oa = vec![0f32; dh];
                let mut ob = vec![0f32; dh];
                let ra = a.attn_row(&args, &mut sa, &mut oa);
                let rb = b.attn_row(&args, &mut sb, &mut ob);
                assert_eq!(ra, rb, "attn_row m/l dh={dh} vis={vis}");
                assert_eq!(oa, ob, "attn_row o dh={dh} vis={vis}");
                assert_eq!(sa[..vis], sb[..vis], "attn_row scores");
            }
            let h = hkv * 2;
            let mut qb = vec![0f32; h * dh];
            let mut eb = vec![0f32; hkv * dh];
            rng.fill_normal_f32(&mut qb);
            rng.fill_normal_f32(&mut eb);
            assert_eq!(a.router_cell(&qb, &eb, h, dh, 2),
                       b.router_cell(&qb, &eb, h, dh, 2),
                       "router_cell dh={dh}");
        }
    }

    /// The scalar flavor keeps the seed bit behavior: multiply-then-add,
    /// sequential reduction.
    #[test]
    fn scalar_flavor_matches_seed_arithmetic() {
        let ks = kernels_for(KernelSpec::Scalar);
        let mut rng = Rng::new(0x5EED);
        let mut x = vec![0f32; 37];
        let mut y = vec![0f32; 37];
        rng.fill_normal_f32(&mut x);
        rng.fill_normal_f32(&mut y);
        let mut got = x.clone();
        ks.fma_row(&mut got, &y, 0.7);
        let want: Vec<f32> =
            x.iter().zip(&y).map(|(o, w)| o + 0.7 * w).collect();
        assert_eq!(got, want);

        let mut qb = vec![0f32; 4 * 9];
        let mut eb = vec![0f32; 2 * 9];
        rng.fill_normal_f32(&mut qb);
        rng.fill_normal_f32(&mut eb);
        let got = ks.router_cell(&qb, &eb, 4, 9, 2);
        let mut acc = 0f32;
        for hi in 0..4 {
            let kv = hi / 2;
            acc += qb[hi * 9..(hi + 1) * 9]
                .iter()
                .zip(&eb[kv * 9..(kv + 1) * 9])
                .map(|(a, b)| a * b)
                .sum::<f32>();
        }
        assert_eq!(got, acc / 4.0);
    }
}
