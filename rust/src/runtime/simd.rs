//! SIMD microkernel layer: vectorized inner loops for the decode hot
//! path, behind a runtime-dispatched [`Kernels`] vtable.
//!
//! The parallel execution layer (PR 1) tiles work across cores, but each
//! tile ran the seed scalar loops — a sequential f32 reduction per dot
//! product and one multiply-add per cycle at best. This module supplies
//! interchangeable kernel *flavors* for the primitive inner ops
//! everything hot routes through (`mm_rows`/`mm_cols` column updates,
//! the `chunk_attn_rows` per-row body, `router_cells` score cells, and
//! the `merge2_row_into`/`finalize_into` tails):
//!
//! * **`scalar`** — the seed kernels, bit-for-bit: plain multiply-then-
//!   add, sequential `k`-ascending reductions. The reference every
//!   golden/replay artifact was produced with (`MOSKA_KERNEL=scalar`).
//! * **`lanes8`** — the portable 8-lane flavor: a fixed-width
//!   lane-striped accumulator (`lanes[i % 8]`) with fused multiply-add
//!   (`f32::mul_add`) and the pinned [`reduce8`] tree. Pure safe Rust;
//!   the fallback on hardware without vector units, and the oracle the
//!   arch-specific flavors are property-tested against.
//! * **`avx2`** / **`neon`** — `std::arch` intrinsics (x86-64 AVX2+FMA,
//!   aarch64 NEON), selected once at startup by runtime feature
//!   detection. Same lane striping, same tail handling, same scalar
//!   [`reduce8`] — **bit-identical to `lanes8` on every input**.
//! * **`avx512`** — 512-bit element-wise ops (matmul column updates,
//!   merge tails, register blocks) layered over the AVX2 reductions.
//!   Reductions keep the 8-lane stripe, and element-wise ops round
//!   identically at any vector width, so `avx512` is bit-identical to
//!   `avx2` (hence to `lanes8`) on every input.
//!
//! ## Packed K/V widening
//!
//! Shared and per-request K/V may be stored packed — `f16`, `bf16`, or
//! `int8` with a per-token-row scale (see
//! [`KvDtype`][crate::tensor::KvDtype]). [`AttnRowArgs`] therefore
//! carries [`KvView`]s rather than `&[f32]`, and every flavor widens
//! K/V rows to f32 *inside* the attention kernel, in registers or a
//! small stack buffer — no separate dequant pass, half (or quarter)
//! the bytes through the memory system. The widening contract:
//!
//! * The scalar conversions ([`f16_to_f32`], [`bf16_to_f32`],
//!   `q as f32 * scale`) are the oracle. The AVX2 widens (F16C
//!   `vcvtph2ps`, bf16 `<<16`, `vpmovsxbd`+`cvtdq2ps`+`mulps`) are
//!   exact or per-element-IEEE — bit-identical to the oracle.
//! * Packed softmax uses [`pexp::exp_pinned`], a pinned-polynomial
//!   `exp` whose AVX2 8-lane form (`exp8`) mirrors it op for op —
//!   so packed attention is bit-identical across *all* flavors
//!   (scalar included; packed rows have no seed bit-history to
//!   preserve, so even `scalar` routes packed inputs through the
//!   shared oracle path).
//! * `f32` K/V keeps the seed semantics unchanged (libm `exp`,
//!   per-flavor F32 bodies) — `MOSKA_KV_DTYPE=f32` output is
//!   bit-for-bit the pre-packing behavior in every flavor.
//!
//! ## Determinism contract
//!
//! The seed contract ("`k` ascends per output element") pinned a purely
//! sequential reduction order, which no vector unit can honor. The SIMD
//! flavors replace it with an equally strict one:
//!
//! * **Reductions** (QK^T dots, router scores) accumulate into a fixed
//!   8-lane stripe — element `i` always lands in lane `i % 8`,
//!   regardless of vector width — and collapse through the pinned
//!   [`reduce8`] tree `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` in scalar
//!   f32 arithmetic. Ragged tails feed lanes `0..n%8` with scalar
//!   `mul_add`, identically in every flavor.
//! * **Element-wise updates** (matmul column updates, the V
//!   accumulation, merge/finalize tails) keep their per-element order;
//!   each element is one fused multiply-add (or IEEE division), which
//!   rounds identically everywhere.
//! * **Register blocks** ([`Kernels::fma_row4`],
//!   [`Kernels::fma_row_block`]) reorder *across* rows, never across
//!   `k` within one output element, and chain per-element `mul_add`s
//!   without intermediate stores — f32 results are bit-identical to
//!   the equivalent sequence of `fma_row` calls in the same flavor.
//!
//! Every flavor still satisfies the parallel-execution contract from
//! PR 1 — tiles own disjoint output regions and run the same per-element
//! order as their serial counterpart — so within a flavor, output is
//! bit-identical across thread counts; and across the SIMD flavors,
//! output is bit-identical, period (asserted by `tests/prop_kernels.rs`
//! and the in-module tests). `scalar` differs from the SIMD flavors in
//! low-order bits on f32 data (different reduction order, no fusion)
//! but decodes the same tokens — `scripts/ci.sh` runs the tier-1 suite
//! and a synthetic disagg token comparison under both.
//!
//! ## Dispatch
//!
//! [`Kernels::global()`] resolves once per process from the
//! `MOSKA_KERNEL` env var (`scalar | simd | lanes8 | avx512`, default
//! auto = best available), and [`set_global_spec`] lets the launcher
//! pin it from `--kernel` / `serving.kernel` config. Each
//! [`NativeBackend`][crate::runtime::NativeBackend] holds a `&'static
//! Kernels` (defaulting to the global) so tests and benches can A/B
//! flavors side by side in one process.

use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::tensor::{bf16_to_f32, f16_to_f32, KvView};

// ---------------------------------------------------------------- flavors

/// Which kernel flavor to run (CLI `--kernel`, `serving.kernel`,
/// `MOSKA_KERNEL`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelSpec {
    /// Best available: AVX-512F > AVX2+FMA > NEON > `lanes8`.
    #[default]
    Auto,
    /// The seed scalar kernels (pre-SIMD bit behavior).
    Scalar,
    /// Explicitly the vectorized path (same resolution as `Auto`).
    Simd,
    /// The portable 8-lane flavor, even when AVX2/NEON is available
    /// (property-test oracle, A/B baseline).
    Lanes8,
    /// The AVX-512F flavor: 512-bit element-wise ops over the AVX2
    /// reductions (bit-identical to `avx2`). Errors loudly when the
    /// CPU lacks AVX-512F.
    Avx512,
}

impl KernelSpec {
    pub fn parse(s: &str) -> Result<KernelSpec> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Ok(KernelSpec::Auto),
            "scalar" | "seed" => Ok(KernelSpec::Scalar),
            "simd" => Ok(KernelSpec::Simd),
            "lanes8" | "fallback" => Ok(KernelSpec::Lanes8),
            "avx512" | "avx-512" => Ok(KernelSpec::Avx512),
            other => bail!(
                "unknown kernel flavor '{other}' \
                 (auto|simd|scalar|lanes8|avx512)"
            ),
        }
    }
}

/// Arguments for one query-row of chunk attention (see
/// [`Kernels::attn_row`]): `ks`/`vs` view the chunk-major `[C, Hkv, dh]`
/// K/V payloads in any [`KvDtype`][crate::tensor::KvDtype] (packed rows
/// are widened inside the kernel), `kv` the GQA KV head this query head
/// reads, `vis` the causally visible key count (> 0).
pub struct AttnRowArgs<'a> {
    pub qrow: &'a [f32],
    pub ks: KvView<'a>,
    pub vs: KvView<'a>,
    pub kv: usize,
    pub hkv: usize,
    pub dh: usize,
    pub vis: usize,
    pub scale: f32,
}

type FmaRowFn = fn(&mut [f32], &[f32], f32);
type FmaRow4Fn = fn(&mut [f32], [&[f32]; 4], [f32; 4]);
type FmaRowBlockFn = fn(&mut [f32], &[f32], &[f32]);
type AttnRowFn = for<'a> fn(&AttnRowArgs<'a>, &mut [f32], &mut [f32])
                            -> (f32, f32);
type RouterCellFn = fn(&[f32], &[f32], usize, usize, usize) -> f32;
type Scale2AddFn = fn(&mut [f32], f32, &[f32], f32);
type DivRowFn = fn(&mut [f32], &[f32], f32);

/// One kernel flavor: the primitive inner ops the hot loops in
/// [`native`][crate::runtime::native] dispatch through. Selected once
/// (per process via [`Kernels::global`], per backend via
/// [`NativeBackend::with_kernel`][crate::runtime::NativeBackend::with_kernel]);
/// the fn pointers are called per row/column-strip, so dispatch cost is
/// amortized over `dh`..`n` elements of work.
pub struct Kernels {
    pub name: &'static str,
    fma_row_fn: FmaRowFn,
    fma_row4_fn: FmaRow4Fn,
    fma_row_block_fn: FmaRowBlockFn,
    attn_row_fn: AttnRowFn,
    router_cell_fn: RouterCellFn,
    scale2_add_fn: Scale2AddFn,
    div_row_fn: DivRowFn,
}

impl Kernels {
    /// `orow[j] += xv * wrow[j]` — the matmul column update (and the
    /// attention V accumulation, which is the same op).
    #[inline]
    pub fn fma_row(&self, orow: &mut [f32], wrow: &[f32], xv: f32) {
        (self.fma_row_fn)(orow, wrow, xv)
    }

    /// Register-blocked quad update: `orow[j] += x[r] * wrows[r][j]`
    /// for `r = 0..4`, chained per element — one `orow` load/store per
    /// four source rows. Bit-identical (within a flavor) to four
    /// sequential [`fma_row`][Kernels::fma_row] calls: chaining
    /// `mul_add`s in registers rounds exactly like storing between
    /// them.
    #[inline]
    pub fn fma_row4(&self, orow: &mut [f32], wrows: [&[f32]; 4],
                    xs: [f32; 4]) {
        (self.fma_row4_fn)(orow, wrows, xs)
    }

    /// Register-blocked row batch: `oblock[r*W + j] += xs[r] * wrow[j]`
    /// for each row `r < xs.len()` of the contiguous `oblock`
    /// (`W = wrow.len()`) — one `wrow` load shared across 2–4 query
    /// rows. Each output element still receives exactly one fused
    /// multiply-add per call, so per-element `k`-order (and hence bit
    /// output, in *every* flavor including scalar) is unchanged from
    /// per-row [`fma_row`][Kernels::fma_row] calls.
    #[inline]
    pub fn fma_row_block(&self, oblock: &mut [f32], wrow: &[f32],
                         xs: &[f32]) {
        (self.fma_row_block_fn)(oblock, wrow, xs)
    }

    /// One query-row chunk-attention body: QK^T scores into
    /// `scores[..vis]`, online-softmax probabilities, V accumulation
    /// into `orow` (must arrive zeroed). Packed K/V rows are widened
    /// in-kernel. Returns `(m, l)`.
    #[inline]
    pub fn attn_row(&self, args: &AttnRowArgs<'_>, scores: &mut [f32],
                    orow: &mut [f32]) -> (f32, f32) {
        (self.attn_row_fn)(args, scores, orow)
    }

    /// One router score cell: mean over `h` query heads of `q_h ·
    /// emb_{kv(h)}`; `qrow` is the row's `[h, dh]` block, `erow` the
    /// chunk's `[hkv, dh]` embedding block.
    #[inline]
    pub fn router_cell(&self, qrow: &[f32], erow: &[f32], h: usize,
                       dh: usize, group: usize) -> f32 {
        (self.router_cell_fn)(qrow, erow, h, dh, group)
    }

    /// `dst[j] = dst[j] * s1 + src[j] * s2` — the LSE-merge o-row tail.
    #[inline]
    pub fn scale2_add(&self, dst: &mut [f32], s1: f32, src: &[f32],
                      s2: f32) {
        (self.scale2_add_fn)(dst, s1, src, s2)
    }

    /// `dst[j] = src[j] / l` — the finalize normalization tail.
    #[inline]
    pub fn div_row(&self, dst: &mut [f32], src: &[f32], l: f32) {
        (self.div_row_fn)(dst, src, l)
    }

    /// The process-wide flavor: `MOSKA_KERNEL` env (or what
    /// [`set_global_spec`] pinned first), default auto-detect. Resolved
    /// once; every free-function kernel wrapper and every backend built
    /// without an explicit flavor uses this.
    pub fn global() -> &'static Kernels {
        *GLOBAL.get_or_init(|| {
            let spec = match std::env::var("MOSKA_KERNEL") {
                Ok(s) => match KernelSpec::parse(&s) {
                    Ok(spec) => spec,
                    Err(e) => panic!("MOSKA_KERNEL: {e}"),
                },
                Err(_) => KernelSpec::Auto,
            };
            // resolve_explicit, NOT kernels_for: `Auto` maps back to
            // this global, which would re-enter the OnceLock init
            resolve_explicit(spec)
        })
    }
}

static GLOBAL: OnceLock<&'static Kernels> = OnceLock::new();

/// Pin the process-wide flavor from launcher config (`--kernel`,
/// `serving.kernel`). Conflicts are rejected loudly and
/// deterministically — a set `MOSKA_KERNEL` env that disagrees with the
/// requested flavor errors here regardless of whether anything resolved
/// [`Kernels::global`] earlier, and so does a second conflicting pin —
/// so an A/B misconfiguration can never silently mix flavors.
pub fn set_global_spec(spec: KernelSpec) -> Result<()> {
    let want = kernels_for(spec);
    if let Ok(s) = std::env::var("MOSKA_KERNEL") {
        let env_spec = KernelSpec::parse(&s)?;
        if env_spec != KernelSpec::Auto {
            anyhow::ensure!(
                std::ptr::eq(kernels_for(env_spec), want),
                "MOSKA_KERNEL={} conflicts with the requested kernel \
                 flavor '{}' — drop one of the two",
                s.trim(), want.name,
            );
        }
    }
    let got = GLOBAL.get_or_init(|| want);
    anyhow::ensure!(
        std::ptr::eq(*got, want),
        "kernel flavor already pinned to '{}' (requested '{}')",
        got.name, want.name,
    );
    Ok(())
}

/// Resolve a flavor spec to its vtable. `Auto` means "no explicit
/// request" and follows the process-global flavor (so `MOSKA_KERNEL`
/// keeps working when a launcher passes its `--kernel` default
/// through); `Simd` explicitly picks the best runtime-detected flavor.
pub fn kernels_for(spec: KernelSpec) -> &'static Kernels {
    match spec {
        KernelSpec::Auto => Kernels::global(),
        explicit => resolve_explicit(explicit),
    }
}

/// [`kernels_for`] minus the `Auto` → global indirection (`Auto` here
/// means auto-*detect*): what the global's own initializer and every
/// explicit spec resolve through.
fn resolve_explicit(spec: KernelSpec) -> &'static Kernels {
    match spec {
        KernelSpec::Scalar => &SCALAR,
        KernelSpec::Lanes8 => &LANES8,
        KernelSpec::Avx512 => avx512_or_panic(),
        KernelSpec::Auto | KernelSpec::Simd => best_simd(),
    }
}

#[cfg(target_arch = "x86_64")]
fn avx512_supported() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
        && std::arch::is_x86_feature_detected!("avx2")
        && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(target_arch = "x86_64")]
fn avx512_or_panic() -> &'static Kernels {
    if avx512_supported() {
        &AVX512
    } else {
        panic!(
            "kernel flavor 'avx512' requested but AVX-512F (+AVX2/FMA) \
             is not available on this CPU"
        )
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn avx512_or_panic() -> &'static Kernels {
    panic!("kernel flavor 'avx512' is only available on x86-64")
}

#[cfg(target_arch = "x86_64")]
fn best_simd() -> &'static Kernels {
    if avx512_supported() {
        &AVX512
    } else if std::arch::is_x86_feature_detected!("avx2")
        && std::arch::is_x86_feature_detected!("fma")
    {
        &AVX2
    } else {
        &LANES8
    }
}

#[cfg(target_arch = "aarch64")]
fn best_simd() -> &'static Kernels {
    &NEON
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn best_simd() -> &'static Kernels {
    &LANES8
}

static SCALAR: Kernels = Kernels {
    name: "scalar",
    fma_row_fn: scalar::fma_row,
    fma_row4_fn: scalar::fma_row4,
    fma_row_block_fn: scalar::fma_row_block,
    attn_row_fn: scalar::attn_row,
    router_cell_fn: scalar::router_cell,
    scale2_add_fn: scalar::scale2_add,
    div_row_fn: scalar::div_row,
};

static LANES8: Kernels = Kernels {
    name: "lanes8",
    fma_row_fn: lanes8::fma_row,
    fma_row4_fn: lanes8::fma_row4,
    fma_row_block_fn: lanes8::fma_row_block,
    attn_row_fn: lanes8::attn_row,
    router_cell_fn: lanes8::router_cell,
    scale2_add_fn: lanes8::scale2_add,
    div_row_fn: scalar::div_row, // IEEE division: identical in any order
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    name: "avx2",
    fma_row_fn: avx2_fma_row,
    fma_row4_fn: avx2_fma_row4,
    fma_row_block_fn: avx2_fma_row_block,
    attn_row_fn: avx2_attn_row,
    router_cell_fn: avx2_router_cell,
    scale2_add_fn: avx2_scale2_add,
    div_row_fn: scalar::div_row,
};

/// 512-bit element-wise ops; reductions and the attention/router bodies
/// reuse the AVX2 paths (a 16-lane dot stripe would break the pinned
/// 8-lane reduction order), so the flavor is bit-identical to `avx2`
/// by construction.
#[cfg(target_arch = "x86_64")]
static AVX512: Kernels = Kernels {
    name: "avx512",
    fma_row_fn: avx512_fma_row,
    fma_row4_fn: avx512_fma_row4,
    fma_row_block_fn: avx512_fma_row_block,
    attn_row_fn: avx2_attn_row,
    router_cell_fn: avx2_router_cell,
    scale2_add_fn: avx512_scale2_add,
    div_row_fn: scalar::div_row,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    name: "neon",
    fma_row_fn: neon_fma_row,
    fma_row4_fn: neon_fma_row4,
    fma_row_block_fn: neon_fma_row_block,
    attn_row_fn: neon_attn_row,
    router_cell_fn: neon_router_cell,
    scale2_add_fn: neon_scale2_add,
    div_row_fn: scalar::div_row,
};

// ------------------------------------------------------- shared helpers

/// The pinned lane-reduction tree every SIMD flavor collapses its
/// 8-lane accumulator through, in scalar f32 arithmetic: pairwise over
/// a vector-width-agnostic pattern (`l0+l4` is what splitting a 256-bit
/// register into 128-bit halves produces naturally; NEON's two 4-lane
/// accumulators and the portable array reduce the same way).
#[inline(always)]
fn reduce8(l: &[f32; 8]) -> f32 {
    let s0 = l[0] + l[4];
    let s1 = l[1] + l[5];
    let s2 = l[2] + l[6];
    let s3 = l[3] + l[7];
    (s0 + s2) + (s1 + s3)
}

/// Ragged-tail accumulation shared by every SIMD flavor: elements
/// `[i0, n)` land in lanes `0..n-i0` with scalar fused multiply-add —
/// the same ops in the same order whether the main loop ran on AVX2,
/// NEON, or the portable stripe.
#[inline(always)]
fn dot_tail(lanes: &mut [f32; 8], a: &[f32], b: &[f32], i0: usize,
            n: usize) {
    let mut t = 0;
    let mut i = i0;
    while i < n {
        lanes[t] = a[i].mul_add(b[i], lanes[t]);
        t += 1;
        i += 1;
    }
}

// ------------------------------------------------------- pinned exp

/// Pinned-polynomial `exp` for packed-K/V softmax. The f32 path keeps
/// libm `exp` (seed bit behavior); packed paths use this polynomial in
/// *every* flavor, so a vectorized 8-lane form (`avx2::exp8`) can
/// mirror it op for op and stay bit-identical.
///
/// Construction (classic Cephes `expf` reduction, order pinned):
/// clamp → `n = rne(x·log2e)` by the magic-number trick (`1.5·2^23`
/// forces round-to-nearest-even in f32) → two-part Cody-Waite `ln 2`
/// reduction `r = x - n·ln2_hi - n·ln2_lo` (each step one `mul_add`) →
/// degree-5 Horner polynomial (all `mul_add`) → `y = r²·p + r + 1` →
/// scale by `2^n` built from exponent bits. Every step is an IEEE op
/// with a fixed order; max relative error ≈ 2 ulp over the clamped
/// domain, more than enough under an int8/f16 quantization floor.
mod pexp {
    pub const EXP_LO: f32 = -87.0;
    pub const EXP_HI: f32 = 88.0;
    pub const LOG2E: f32 = 1.442_695_04;
    /// `1.5 · 2^23`: adding it to `|t| ≤ 128` forces f32
    /// round-to-nearest-even at integer granularity.
    pub const MAGIC: f32 = 12_582_912.0;
    pub const LN2_HI: f32 = 0.693_359_375;
    pub const LN2_LO: f32 = -2.121_944_4e-4;
    pub const C5: f32 = 1.987_569_15e-4;
    pub const C4: f32 = 1.398_199_95e-3;
    pub const C3: f32 = 8.333_451_9e-3;
    pub const C2: f32 = 4.166_579_6e-2;
    pub const C1: f32 = 1.666_666_55e-1;
    pub const C0: f32 = 5.000_000_1e-1;

    #[inline(always)]
    pub fn exp_pinned(x: f32) -> f32 {
        // clamp with min/max *comparison* semantics (mirrors
        // `_mm256_min_ps`/`_mm256_max_ps`, incl. NaN → HI)
        let x = if x < EXP_HI { x } else { EXP_HI };
        let x = if x > EXP_LO { x } else { EXP_LO };
        let t = x.mul_add(LOG2E, MAGIC);
        let nf = t - MAGIC; // exactly integral by construction
        let n = nf as i32; // truncation of an exact integer is exact
        let r = nf.mul_add(-LN2_HI, x);
        let r = nf.mul_add(-LN2_LO, r);
        let mut p = C5;
        p = p.mul_add(r, C4);
        p = p.mul_add(r, C3);
        p = p.mul_add(r, C2);
        p = p.mul_add(r, C1);
        p = p.mul_add(r, C0);
        let y = (r * r).mul_add(p, r) + 1.0;
        // 2^n for n in [-126, 127]: plain exponent-field construction
        y * f32::from_bits((((n + 127) as u32) << 23))
    }
}

// ------------------------------------------------------- packed oracle

/// The shared packed-K/V attention path: widen one K/V sub-row at a
/// time into a stack buffer, then run the `lanes8` dot/fma bodies and
/// [`pexp::exp_pinned`]. This single implementation serves the scalar,
/// lanes8, and NEON flavors (packed data has no seed bit-history, so
/// there is nothing for `scalar` to preserve); `avx2::attn_row_packed`
/// reimplements it with F16C/AVX2 widening and `exp8`, each step
/// bit-identical, so packed attention output is identical across every
/// flavor — the property `tests/prop_kernels.rs` pins.
mod packed {
    use super::{lanes8, pexp, AttnRowArgs};
    use crate::tensor::{bf16_to_f32, f16_to_f32, KvView};

    /// Stack-buffer bound for one widened K/V sub-row (`dh` f32s).
    pub const MAX_DH: usize = 512;

    /// Widen `view[base .. base + buf.len()]` to f32. For `I8` the
    /// per-token-row scale is `scales[base / row_elems]` — a K/V
    /// sub-row `(tok*hkv + kv)*dh .. +dh` never crosses a token row,
    /// so one scale covers the whole slice.
    #[inline(always)]
    pub fn widen_row(view: KvView<'_>, base: usize, buf: &mut [f32]) {
        let dh = buf.len();
        match view {
            KvView::F32(d) => buf.copy_from_slice(&d[base..base + dh]),
            KvView::F16(d) => {
                for (o, &h) in buf.iter_mut().zip(&d[base..base + dh]) {
                    *o = f16_to_f32(h);
                }
            }
            KvView::Bf16(d) => {
                for (o, &h) in buf.iter_mut().zip(&d[base..base + dh]) {
                    *o = bf16_to_f32(h);
                }
            }
            KvView::I8 { q, scales, row_elems } => {
                let s = scales[base / row_elems];
                for (o, &x) in buf.iter_mut().zip(&q[base..base + dh]) {
                    *o = x as f32 * s;
                }
            }
        }
    }

    pub fn attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                    orow: &mut [f32]) -> (f32, f32) {
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        assert!(dh <= MAX_DH,
                "head_dim {dh} exceeds packed-widen buffer {MAX_DH}");
        let mut buf = [0f32; MAX_DH];
        let buf = &mut buf[..dh];
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            widen_row(a.ks, base, buf);
            let s = lanes8::dot8(a.qrow, buf) * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        for j in 0..a.vis {
            let p = pexp::exp_pinned(scores[j] - mx);
            li += p;
            let base = (j * hkv + kv) * dh;
            widen_row(a.vs, base, buf);
            lanes8::fma_row(orow, buf, p);
        }
        (mx, li)
    }
}

// ------------------------------------------------------- scalar (seed)

/// The seed kernels, arithmetic preserved bit-for-bit on f32 data:
/// multiply *then* add (no fusion), sequential reductions.
/// `MOSKA_KERNEL=scalar` reproduces pre-SIMD output exactly
/// (regression-tested against inline references in
/// `tests/prop_kernels.rs`). Packed K/V routes through the shared
/// [`packed`] oracle — packed rows have no seed history to preserve.
mod scalar {
    use super::AttnRowArgs;
    use crate::tensor::KvView;

    pub fn fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
        for (o, &wv) in orow.iter_mut().zip(wrow) {
            *o += xv * wv;
        }
    }

    pub fn fma_row4(orow: &mut [f32], wrows: [&[f32]; 4],
                    xs: [f32; 4]) {
        // four sequential seed updates — trivially seed-identical
        for (w, &xv) in wrows.iter().zip(xs.iter()) {
            fma_row(orow, w, xv);
        }
    }

    pub fn fma_row_block(oblock: &mut [f32], wrow: &[f32], xs: &[f32]) {
        let w = wrow.len();
        for (r, &xv) in xs.iter().enumerate() {
            fma_row(&mut oblock[r * w..(r + 1) * w], wrow, xv);
        }
    }

    pub fn attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                    orow: &mut [f32]) -> (f32, f32) {
        let (ks, vs) = match (a.ks, a.vs) {
            (KvView::F32(k), KvView::F32(v)) => (k, v),
            _ => return super::packed::attn_row(a, scores, orow),
        };
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            let krow = &ks[base..base + dh];
            let dot: f32 =
                a.qrow.iter().zip(krow).map(|(x, y)| x * y).sum();
            let s = dot * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        for j in 0..a.vis {
            let p = (scores[j] - mx).exp();
            li += p;
            let base = (j * hkv + kv) * dh;
            let vrow = &vs[base..base + dh];
            for (oo, &vv) in orow.iter_mut().zip(vrow) {
                *oo += p * vv;
            }
        }
        (mx, li)
    }

    pub fn router_cell(qrow: &[f32], erow: &[f32], h: usize, dh: usize,
                       group: usize) -> f32 {
        let mut acc = 0f32;
        for hi in 0..h {
            let kv = hi / group;
            let q = &qrow[hi * dh..(hi + 1) * dh];
            let e = &erow[kv * dh..(kv + 1) * dh];
            acc += q.iter().zip(e).map(|(x, y)| x * y).sum::<f32>();
        }
        acc / h as f32
    }

    pub fn scale2_add(dst: &mut [f32], s1: f32, src: &[f32], s2: f32) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = *d * s1 + s * s2;
        }
    }

    pub fn div_row(dst: &mut [f32], src: &[f32], l: f32) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s / l;
        }
    }
}

// ---------------------------------------------------- lanes8 (portable)

/// The portable 8-lane flavor: defines the SIMD semantics in safe Rust.
/// `f32::mul_add` is the IEEE fused op (identical to AVX2 `vfmadd` /
/// NEON `fmla` bit-for-bit); the stripe + [`super::reduce8`] pin the
/// reduction order the vector flavors reproduce.
mod lanes8 {
    use super::{dot_tail, reduce8, AttnRowArgs};
    use crate::tensor::KvView;

    #[inline(always)]
    pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut lanes = [0f32; 8];
        let mut i = 0;
        while i + 8 <= n {
            for j in 0..8 {
                lanes[j] = a[i + j].mul_add(b[i + j], lanes[j]);
            }
            i += 8;
        }
        dot_tail(&mut lanes, a, b, i, n);
        reduce8(&lanes)
    }

    pub fn fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
        for (o, &wv) in orow.iter_mut().zip(wrow) {
            *o = wv.mul_add(xv, *o);
        }
    }

    pub fn fma_row4(orow: &mut [f32], wrows: [&[f32]; 4],
                    xs: [f32; 4]) {
        for (j, o) in orow.iter_mut().enumerate() {
            let mut acc = *o;
            acc = wrows[0][j].mul_add(xs[0], acc);
            acc = wrows[1][j].mul_add(xs[1], acc);
            acc = wrows[2][j].mul_add(xs[2], acc);
            acc = wrows[3][j].mul_add(xs[3], acc);
            *o = acc;
        }
    }

    pub fn fma_row_block(oblock: &mut [f32], wrow: &[f32], xs: &[f32]) {
        let w = wrow.len();
        for (r, &xv) in xs.iter().enumerate() {
            fma_row(&mut oblock[r * w..(r + 1) * w], wrow, xv);
        }
    }

    pub fn attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                    orow: &mut [f32]) -> (f32, f32) {
        let (ks, vs) = match (a.ks, a.vs) {
            (KvView::F32(k), KvView::F32(v)) => (k, v),
            _ => return super::packed::attn_row(a, scores, orow),
        };
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            let s = dot8(a.qrow, &ks[base..base + dh]) * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        let mut j = 0;
        // V pass register-blocked by 4 rows; p/li order stays j-ascending
        while j + 4 <= a.vis {
            let mut ps = [0f32; 4];
            for (t, p) in ps.iter_mut().enumerate() {
                *p = (scores[j + t] - mx).exp();
                li += *p;
            }
            let b = [((j) * hkv + kv) * dh,
                     ((j + 1) * hkv + kv) * dh,
                     ((j + 2) * hkv + kv) * dh,
                     ((j + 3) * hkv + kv) * dh];
            fma_row4(orow,
                     [&vs[b[0]..b[0] + dh], &vs[b[1]..b[1] + dh],
                      &vs[b[2]..b[2] + dh], &vs[b[3]..b[3] + dh]],
                     ps);
            j += 4;
        }
        while j < a.vis {
            let p = (scores[j] - mx).exp();
            li += p;
            let base = (j * hkv + kv) * dh;
            fma_row(orow, &vs[base..base + dh], p);
            j += 1;
        }
        (mx, li)
    }

    pub fn router_cell(qrow: &[f32], erow: &[f32], h: usize, dh: usize,
                       group: usize) -> f32 {
        let mut acc = 0f32;
        for hi in 0..h {
            let kv = hi / group;
            acc += dot8(&qrow[hi * dh..(hi + 1) * dh],
                        &erow[kv * dh..(kv + 1) * dh]);
        }
        acc / h as f32
    }

    pub fn scale2_add(dst: &mut [f32], s1: f32, src: &[f32], s2: f32) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = s.mul_add(s2, *d * s1);
        }
    }
}

// -------------------------------------------------------- avx2 (x86-64)

/// Cached F16C probe for the AVX2 widening path (`vcvtph2ps`); the
/// scalar [`f16_to_f32`] fallback is bit-identical, so this only
/// affects speed.
#[cfg(target_arch = "x86_64")]
fn f16c_available() -> bool {
    std::arch::is_x86_feature_detected!("f16c")
}

/// AVX2+FMA implementations. Every `unsafe fn` here requires AVX2 and
/// FMA support; the safe wrappers below are only reachable through the
/// [`AVX2`] / [`AVX512`] tables, which [`best_simd`] constructs
/// exclusively behind `is_x86_feature_detected!` — that detection is
/// the safety proof for every call site.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::packed::MAX_DH;
    use super::{dot_tail, pexp, reduce8, AttnRowArgs};
    use crate::tensor::{f16_to_f32, KvView};

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot8(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut lanes = [0f32; 8];
        let mut i = 0;
        unsafe {
            let mut acc = _mm256_setzero_ps();
            while i + 8 <= n {
                let av = _mm256_loadu_ps(a.as_ptr().add(i));
                let bv = _mm256_loadu_ps(b.as_ptr().add(i));
                acc = _mm256_fmadd_ps(av, bv, acc);
                i += 8;
            }
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        }
        dot_tail(&mut lanes, a, b, i, n);
        reduce8(&lanes)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
        let n = orow.len().min(wrow.len());
        let mut i = 0;
        unsafe {
            let xvv = _mm256_set1_ps(xv);
            // 4x unrolled: same per-element fused op, better ILP
            while i + 32 <= n {
                for u in [0usize, 8, 16, 24] {
                    let o = _mm256_loadu_ps(orow.as_ptr().add(i + u));
                    let w = _mm256_loadu_ps(wrow.as_ptr().add(i + u));
                    _mm256_storeu_ps(orow.as_mut_ptr().add(i + u),
                                     _mm256_fmadd_ps(w, xvv, o));
                }
                i += 32;
            }
            while i + 8 <= n {
                let o = _mm256_loadu_ps(orow.as_ptr().add(i));
                let w = _mm256_loadu_ps(wrow.as_ptr().add(i));
                _mm256_storeu_ps(orow.as_mut_ptr().add(i),
                                 _mm256_fmadd_ps(w, xvv, o));
                i += 8;
            }
        }
        while i < n {
            orow[i] = wrow[i].mul_add(xv, orow[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn fma_row4(orow: &mut [f32], wrows: [&[f32]; 4],
                           xs: [f32; 4]) {
        let n = orow.len();
        debug_assert!(wrows.iter().all(|w| w.len() >= n));
        let mut i = 0;
        unsafe {
            let x0 = _mm256_set1_ps(xs[0]);
            let x1 = _mm256_set1_ps(xs[1]);
            let x2 = _mm256_set1_ps(xs[2]);
            let x3 = _mm256_set1_ps(xs[3]);
            while i + 8 <= n {
                let mut o = _mm256_loadu_ps(orow.as_ptr().add(i));
                o = _mm256_fmadd_ps(
                    _mm256_loadu_ps(wrows[0].as_ptr().add(i)), x0, o);
                o = _mm256_fmadd_ps(
                    _mm256_loadu_ps(wrows[1].as_ptr().add(i)), x1, o);
                o = _mm256_fmadd_ps(
                    _mm256_loadu_ps(wrows[2].as_ptr().add(i)), x2, o);
                o = _mm256_fmadd_ps(
                    _mm256_loadu_ps(wrows[3].as_ptr().add(i)), x3, o);
                _mm256_storeu_ps(orow.as_mut_ptr().add(i), o);
                i += 8;
            }
        }
        while i < n {
            let mut acc = orow[i];
            acc = wrows[0][i].mul_add(xs[0], acc);
            acc = wrows[1][i].mul_add(xs[1], acc);
            acc = wrows[2][i].mul_add(xs[2], acc);
            acc = wrows[3][i].mul_add(xs[3], acc);
            orow[i] = acc;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn fma_row_block(oblock: &mut [f32], wrow: &[f32],
                                xs: &[f32]) {
        let w = wrow.len();
        let rows = xs.len();
        debug_assert!(oblock.len() >= rows * w);
        let mut i = 0;
        unsafe {
            while i + 8 <= w {
                let wv = _mm256_loadu_ps(wrow.as_ptr().add(i));
                for (r, &xv) in xs.iter().enumerate() {
                    let op = oblock.as_mut_ptr().add(r * w + i);
                    let o = _mm256_loadu_ps(op);
                    _mm256_storeu_ps(
                        op, _mm256_fmadd_ps(wv, _mm256_set1_ps(xv), o));
                }
                i += 8;
            }
        }
        while i < w {
            for (r, &xv) in xs.iter().enumerate() {
                oblock[r * w + i] =
                    wrow[i].mul_add(xv, oblock[r * w + i]);
            }
            i += 1;
        }
    }

    /// 8-lane mirror of [`pexp::exp_pinned`], op for op: min/max
    /// clamp, fmadd magic-rounding, truncating cvt (exact on the
    /// integral `nf`), two fmadd Cody-Waite steps, five fmadd Horner
    /// steps, `r²·p + r` fmadd, `+1`, exponent-field `2^n`, final mul.
    /// Every step is the same IEEE op on the same operands as the
    /// scalar form — bit-identical per lane.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn exp8(x: __m256) -> __m256 {
        unsafe {
            let x = _mm256_max_ps(
                _mm256_min_ps(x, _mm256_set1_ps(pexp::EXP_HI)),
                _mm256_set1_ps(pexp::EXP_LO));
            let magic = _mm256_set1_ps(pexp::MAGIC);
            let t = _mm256_fmadd_ps(
                x, _mm256_set1_ps(pexp::LOG2E), magic);
            let nf = _mm256_sub_ps(t, magic);
            let n = _mm256_cvttps_epi32(nf);
            let r = _mm256_fmadd_ps(
                nf, _mm256_set1_ps(-pexp::LN2_HI), x);
            let r = _mm256_fmadd_ps(
                nf, _mm256_set1_ps(-pexp::LN2_LO), r);
            let mut p = _mm256_set1_ps(pexp::C5);
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(pexp::C4));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(pexp::C3));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(pexp::C2));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(pexp::C1));
            p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(pexp::C0));
            let y = _mm256_add_ps(
                _mm256_fmadd_ps(_mm256_mul_ps(r, r), p, r),
                _mm256_set1_ps(1.0));
            let sc = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(
                _mm256_add_epi32(n, _mm256_set1_epi32(127))));
            _mm256_mul_ps(y, sc)
        }
    }

    /// F16C widening (`vcvtph2ps` is an exact conversion — identical
    /// to scalar [`f16_to_f32`] on every finite input).
    #[target_feature(enable = "avx2,fma,f16c")]
    unsafe fn widen_f16(src: &[u16], buf: &mut [f32]) {
        let n = src.len().min(buf.len());
        let mut i = 0;
        unsafe {
            while i + 8 <= n {
                let h = _mm_loadu_si128(
                    src.as_ptr().add(i) as *const __m128i);
                _mm256_storeu_ps(buf.as_mut_ptr().add(i),
                                 _mm256_cvtph_ps(h));
                i += 8;
            }
        }
        while i < n {
            buf[i] = f16_to_f32(src[i]);
            i += 1;
        }
    }

    /// bf16 widening: zero-extend to 32 bits, shift into the high
    /// half — exact by definition of bf16.
    #[target_feature(enable = "avx2")]
    unsafe fn widen_bf16(src: &[u16], buf: &mut [f32]) {
        let n = src.len().min(buf.len());
        let mut i = 0;
        unsafe {
            while i + 8 <= n {
                let h = _mm_loadu_si128(
                    src.as_ptr().add(i) as *const __m128i);
                let w = _mm256_slli_epi32::<16>(
                    _mm256_cvtepu16_epi32(h));
                _mm256_storeu_ps(buf.as_mut_ptr().add(i),
                                 _mm256_castsi256_ps(w));
                i += 8;
            }
        }
        while i < n {
            buf[i] = f32::from_bits((src[i] as u32) << 16);
            i += 1;
        }
    }

    /// int8 widening: sign-extend, exact int→f32 convert, one IEEE
    /// multiply by the row scale — per-element identical to the scalar
    /// `q as f32 * scale`.
    #[target_feature(enable = "avx2")]
    unsafe fn widen_i8(src: &[i8], scale: f32, buf: &mut [f32]) {
        let n = src.len().min(buf.len());
        let mut i = 0;
        unsafe {
            let sv = _mm256_set1_ps(scale);
            while i + 8 <= n {
                let b = _mm_loadl_epi64(
                    src.as_ptr().add(i) as *const __m128i);
                let w = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(b));
                _mm256_storeu_ps(buf.as_mut_ptr().add(i),
                                 _mm256_mul_ps(w, sv));
                i += 8;
            }
        }
        while i < n {
            buf[i] = src[i] as f32 * scale;
            i += 1;
        }
    }

    /// Vectorized form of [`super::packed::widen_row`]; every branch
    /// is exact/per-element-IEEE, hence bit-identical to the scalar
    /// oracle.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn widen_row(view: KvView<'_>, base: usize,
                        buf: &mut [f32]) {
        let dh = buf.len();
        match view {
            KvView::F32(d) => buf.copy_from_slice(&d[base..base + dh]),
            KvView::F16(d) => {
                if super::f16c_available() {
                    unsafe { widen_f16(&d[base..base + dh], buf) }
                } else {
                    for (o, &h) in
                        buf.iter_mut().zip(&d[base..base + dh])
                    {
                        *o = f16_to_f32(h);
                    }
                }
            }
            KvView::Bf16(d) => unsafe {
                widen_bf16(&d[base..base + dh], buf)
            },
            KvView::I8 { q, scales, row_elems } => {
                let s = scales[base / row_elems];
                unsafe { widen_i8(&q[base..base + dh], s, buf) }
            }
        }
    }

    /// Packed-K/V attention: the AVX2 rebuild of
    /// [`super::packed::attn_row`], step-for-step bit-identical —
    /// exact widening, the shared `dot8`/`fma_row` bodies, `exp8`
    /// blocks with a scalar `exp_pinned` tail, `li` accumulated in
    /// ascending-`j` order.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn attn_row_packed(a: &AttnRowArgs<'_>,
                                  scores: &mut [f32],
                                  orow: &mut [f32]) -> (f32, f32) {
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        assert!(dh <= MAX_DH,
                "head_dim {dh} exceeds packed-widen buffer {MAX_DH}");
        let mut buf = [0f32; MAX_DH];
        let buf = &mut buf[..dh];
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            unsafe { widen_row(a.ks, base, buf) };
            let s = unsafe { dot8(a.qrow, buf) } * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        let mut j = 0;
        unsafe {
            let mxv = _mm256_set1_ps(mx);
            while j + 8 <= a.vis {
                let sv = _mm256_loadu_ps(scores.as_ptr().add(j));
                let pv = exp8(_mm256_sub_ps(sv, mxv));
                let mut ps = [0f32; 8];
                _mm256_storeu_ps(ps.as_mut_ptr(), pv);
                for (t, &p) in ps.iter().enumerate() {
                    li += p;
                    let base = ((j + t) * hkv + kv) * dh;
                    widen_row(a.vs, base, buf);
                    fma_row(orow, buf, p);
                }
                j += 8;
            }
        }
        while j < a.vis {
            let p = pexp::exp_pinned(scores[j] - mx);
            li += p;
            let base = (j * hkv + kv) * dh;
            unsafe { widen_row(a.vs, base, buf) };
            unsafe { fma_row(orow, buf, p) };
            j += 1;
        }
        (mx, li)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                           orow: &mut [f32]) -> (f32, f32) {
        let (ks, vs) = match (a.ks, a.vs) {
            (KvView::F32(k), KvView::F32(v)) => (k, v),
            _ => return unsafe { attn_row_packed(a, scores, orow) },
        };
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            let s = unsafe { dot8(a.qrow, &ks[base..base + dh]) }
                * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        let mut j = 0;
        // V pass register-blocked by 4 rows; p/li order stays
        // j-ascending, chained fmadds round like the sequential form
        while j + 4 <= a.vis {
            let mut ps = [0f32; 4];
            for (t, p) in ps.iter_mut().enumerate() {
                *p = (scores[j + t] - mx).exp();
                li += *p;
            }
            let b = [((j) * hkv + kv) * dh,
                     ((j + 1) * hkv + kv) * dh,
                     ((j + 2) * hkv + kv) * dh,
                     ((j + 3) * hkv + kv) * dh];
            unsafe {
                fma_row4(orow,
                         [&vs[b[0]..b[0] + dh], &vs[b[1]..b[1] + dh],
                          &vs[b[2]..b[2] + dh], &vs[b[3]..b[3] + dh]],
                         ps)
            };
            j += 4;
        }
        while j < a.vis {
            let p = (scores[j] - mx).exp();
            li += p;
            let base = (j * hkv + kv) * dh;
            unsafe { fma_row(orow, &vs[base..base + dh], p) };
            j += 1;
        }
        (mx, li)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn router_cell(qrow: &[f32], erow: &[f32], h: usize,
                              dh: usize, group: usize) -> f32 {
        let mut acc = 0f32;
        for hi in 0..h {
            let kv = hi / group;
            acc += unsafe {
                dot8(&qrow[hi * dh..(hi + 1) * dh],
                     &erow[kv * dh..(kv + 1) * dh])
            };
        }
        acc / h as f32
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale2_add(dst: &mut [f32], s1: f32, src: &[f32],
                             s2: f32) {
        let n = dst.len().min(src.len());
        let mut i = 0;
        unsafe {
            let s1v = _mm256_set1_ps(s1);
            let s2v = _mm256_set1_ps(s2);
            while i + 8 <= n {
                let d = _mm256_loadu_ps(dst.as_ptr().add(i));
                let s = _mm256_loadu_ps(src.as_ptr().add(i));
                let r = _mm256_fmadd_ps(s, s2v, _mm256_mul_ps(d, s1v));
                _mm256_storeu_ps(dst.as_mut_ptr().add(i), r);
                i += 8;
            }
        }
        while i < n {
            dst[i] = src[i].mul_add(s2, dst[i] * s1);
            i += 1;
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
    // SAFETY: the AVX2/AVX512 tables are only selectable after feature
    // detection.
    unsafe { avx2::fma_row(orow, wrow, xv) }
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_row4(orow: &mut [f32], wrows: [&[f32]; 4], xs: [f32; 4]) {
    // SAFETY: as above.
    unsafe { avx2::fma_row4(orow, wrows, xs) }
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_row_block(oblock: &mut [f32], wrow: &[f32], xs: &[f32]) {
    // SAFETY: as above.
    unsafe { avx2::fma_row_block(oblock, wrow, xs) }
}

#[cfg(target_arch = "x86_64")]
fn avx2_attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                 orow: &mut [f32]) -> (f32, f32) {
    // SAFETY: as above.
    unsafe { avx2::attn_row(a, scores, orow) }
}

#[cfg(target_arch = "x86_64")]
fn avx2_router_cell(qrow: &[f32], erow: &[f32], h: usize, dh: usize,
                    group: usize) -> f32 {
    // SAFETY: as above.
    unsafe { avx2::router_cell(qrow, erow, h, dh, group) }
}

#[cfg(target_arch = "x86_64")]
fn avx2_scale2_add(dst: &mut [f32], s1: f32, src: &[f32], s2: f32) {
    // SAFETY: as above.
    unsafe { avx2::scale2_add(dst, s1, src, s2) }
}

// ------------------------------------------------------ avx512 (x86-64)

/// AVX-512F implementations — *element-wise ops only*. A 16-lane dot
/// accumulator would break the pinned 8-lane stripe, so reductions
/// (and the attention/router bodies built on them) stay on the AVX2
/// paths; here only the ops where any vector width produces identical
/// per-element IEEE results go 512-bit wide. Consequence: `avx512` is
/// bit-identical to `avx2` on every input, by construction.
#[cfg(target_arch = "x86_64")]
mod avx512 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx512f")]
    pub unsafe fn fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
        let n = orow.len().min(wrow.len());
        let mut i = 0;
        unsafe {
            let xvv = _mm512_set1_ps(xv);
            while i + 16 <= n {
                let o = _mm512_loadu_ps(orow.as_ptr().add(i));
                let w = _mm512_loadu_ps(wrow.as_ptr().add(i));
                _mm512_storeu_ps(orow.as_mut_ptr().add(i),
                                 _mm512_fmadd_ps(w, xvv, o));
                i += 16;
            }
        }
        while i < n {
            orow[i] = wrow[i].mul_add(xv, orow[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn fma_row4(orow: &mut [f32], wrows: [&[f32]; 4],
                           xs: [f32; 4]) {
        let n = orow.len();
        debug_assert!(wrows.iter().all(|w| w.len() >= n));
        let mut i = 0;
        unsafe {
            let x0 = _mm512_set1_ps(xs[0]);
            let x1 = _mm512_set1_ps(xs[1]);
            let x2 = _mm512_set1_ps(xs[2]);
            let x3 = _mm512_set1_ps(xs[3]);
            while i + 16 <= n {
                let mut o = _mm512_loadu_ps(orow.as_ptr().add(i));
                o = _mm512_fmadd_ps(
                    _mm512_loadu_ps(wrows[0].as_ptr().add(i)), x0, o);
                o = _mm512_fmadd_ps(
                    _mm512_loadu_ps(wrows[1].as_ptr().add(i)), x1, o);
                o = _mm512_fmadd_ps(
                    _mm512_loadu_ps(wrows[2].as_ptr().add(i)), x2, o);
                o = _mm512_fmadd_ps(
                    _mm512_loadu_ps(wrows[3].as_ptr().add(i)), x3, o);
                _mm512_storeu_ps(orow.as_mut_ptr().add(i), o);
                i += 16;
            }
        }
        while i < n {
            let mut acc = orow[i];
            acc = wrows[0][i].mul_add(xs[0], acc);
            acc = wrows[1][i].mul_add(xs[1], acc);
            acc = wrows[2][i].mul_add(xs[2], acc);
            acc = wrows[3][i].mul_add(xs[3], acc);
            orow[i] = acc;
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn fma_row_block(oblock: &mut [f32], wrow: &[f32],
                                xs: &[f32]) {
        let w = wrow.len();
        let rows = xs.len();
        debug_assert!(oblock.len() >= rows * w);
        let mut i = 0;
        unsafe {
            while i + 16 <= w {
                let wv = _mm512_loadu_ps(wrow.as_ptr().add(i));
                for (r, &xv) in xs.iter().enumerate() {
                    let op = oblock.as_mut_ptr().add(r * w + i);
                    let o = _mm512_loadu_ps(op);
                    _mm512_storeu_ps(
                        op,
                        _mm512_fmadd_ps(wv, _mm512_set1_ps(xv), o));
                }
                i += 16;
            }
        }
        while i < w {
            for (r, &xv) in xs.iter().enumerate() {
                oblock[r * w + i] =
                    wrow[i].mul_add(xv, oblock[r * w + i]);
            }
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    pub unsafe fn scale2_add(dst: &mut [f32], s1: f32, src: &[f32],
                             s2: f32) {
        let n = dst.len().min(src.len());
        let mut i = 0;
        unsafe {
            let s1v = _mm512_set1_ps(s1);
            let s2v = _mm512_set1_ps(s2);
            while i + 16 <= n {
                let d = _mm512_loadu_ps(dst.as_ptr().add(i));
                let s = _mm512_loadu_ps(src.as_ptr().add(i));
                let r = _mm512_fmadd_ps(s, s2v, _mm512_mul_ps(d, s1v));
                _mm512_storeu_ps(dst.as_mut_ptr().add(i), r);
                i += 16;
            }
        }
        while i < n {
            dst[i] = src[i].mul_add(s2, dst[i] * s1);
            i += 1;
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx512_fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
    // SAFETY: the AVX512 table is only selectable after
    // `avx512_supported()` detection.
    unsafe { avx512::fma_row(orow, wrow, xv) }
}

#[cfg(target_arch = "x86_64")]
fn avx512_fma_row4(orow: &mut [f32], wrows: [&[f32]; 4],
                   xs: [f32; 4]) {
    // SAFETY: as above.
    unsafe { avx512::fma_row4(orow, wrows, xs) }
}

#[cfg(target_arch = "x86_64")]
fn avx512_fma_row_block(oblock: &mut [f32], wrow: &[f32], xs: &[f32]) {
    // SAFETY: as above.
    unsafe { avx512::fma_row_block(oblock, wrow, xs) }
}

#[cfg(target_arch = "x86_64")]
fn avx512_scale2_add(dst: &mut [f32], s1: f32, src: &[f32], s2: f32) {
    // SAFETY: as above.
    unsafe { avx512::scale2_add(dst, s1, src, s2) }
}

// ------------------------------------------------------- neon (aarch64)

/// NEON implementations (two 4-lane accumulators = the same 8-lane
/// stripe). NEON is part of the aarch64 baseline, so detection cannot
/// fail; the `target_feature` + safe-wrapper structure mirrors AVX2 for
/// uniformity (and for toolchains predating safe target-feature calls).
/// Packed K/V routes through the shared [`packed`] oracle (scalar
/// widening + `lanes8` bodies) — correct and bit-identical everywhere;
/// a vectorized NEON widen can follow the AVX2 pattern later.
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    use super::{dot_tail, reduce8, AttnRowArgs};
    use crate::tensor::KvView;

    #[target_feature(enable = "neon")]
    pub unsafe fn dot8(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut lanes = [0f32; 8];
        let mut i = 0;
        unsafe {
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            while i + 8 <= n {
                let a0 = vld1q_f32(a.as_ptr().add(i));
                let b0 = vld1q_f32(b.as_ptr().add(i));
                let a1 = vld1q_f32(a.as_ptr().add(i + 4));
                let b1 = vld1q_f32(b.as_ptr().add(i + 4));
                acc0 = vfmaq_f32(acc0, a0, b0);
                acc1 = vfmaq_f32(acc1, a1, b1);
                i += 8;
            }
            vst1q_f32(lanes.as_mut_ptr(), acc0);
            vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        }
        dot_tail(&mut lanes, a, b, i, n);
        reduce8(&lanes)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
        let n = orow.len().min(wrow.len());
        let mut i = 0;
        unsafe {
            let xvv = vdupq_n_f32(xv);
            while i + 8 <= n {
                let o0 = vld1q_f32(orow.as_ptr().add(i));
                let w0 = vld1q_f32(wrow.as_ptr().add(i));
                let o1 = vld1q_f32(orow.as_ptr().add(i + 4));
                let w1 = vld1q_f32(wrow.as_ptr().add(i + 4));
                vst1q_f32(orow.as_mut_ptr().add(i),
                          vfmaq_f32(o0, w0, xvv));
                vst1q_f32(orow.as_mut_ptr().add(i + 4),
                          vfmaq_f32(o1, w1, xvv));
                i += 8;
            }
            while i + 4 <= n {
                let o = vld1q_f32(orow.as_ptr().add(i));
                let w = vld1q_f32(wrow.as_ptr().add(i));
                vst1q_f32(orow.as_mut_ptr().add(i),
                          vfmaq_f32(o, w, xvv));
                i += 4;
            }
        }
        while i < n {
            orow[i] = wrow[i].mul_add(xv, orow[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn fma_row4(orow: &mut [f32], wrows: [&[f32]; 4],
                           xs: [f32; 4]) {
        let n = orow.len();
        debug_assert!(wrows.iter().all(|w| w.len() >= n));
        let mut i = 0;
        unsafe {
            let x0 = vdupq_n_f32(xs[0]);
            let x1 = vdupq_n_f32(xs[1]);
            let x2 = vdupq_n_f32(xs[2]);
            let x3 = vdupq_n_f32(xs[3]);
            while i + 4 <= n {
                let mut o = vld1q_f32(orow.as_ptr().add(i));
                o = vfmaq_f32(o, vld1q_f32(wrows[0].as_ptr().add(i)),
                              x0);
                o = vfmaq_f32(o, vld1q_f32(wrows[1].as_ptr().add(i)),
                              x1);
                o = vfmaq_f32(o, vld1q_f32(wrows[2].as_ptr().add(i)),
                              x2);
                o = vfmaq_f32(o, vld1q_f32(wrows[3].as_ptr().add(i)),
                              x3);
                vst1q_f32(orow.as_mut_ptr().add(i), o);
                i += 4;
            }
        }
        while i < n {
            let mut acc = orow[i];
            acc = wrows[0][i].mul_add(xs[0], acc);
            acc = wrows[1][i].mul_add(xs[1], acc);
            acc = wrows[2][i].mul_add(xs[2], acc);
            acc = wrows[3][i].mul_add(xs[3], acc);
            orow[i] = acc;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn fma_row_block(oblock: &mut [f32], wrow: &[f32],
                                xs: &[f32]) {
        let w = wrow.len();
        let rows = xs.len();
        debug_assert!(oblock.len() >= rows * w);
        let mut i = 0;
        unsafe {
            while i + 4 <= w {
                let wv = vld1q_f32(wrow.as_ptr().add(i));
                for (r, &xv) in xs.iter().enumerate() {
                    let op = oblock.as_mut_ptr().add(r * w + i);
                    let o = vld1q_f32(op);
                    vst1q_f32(op, vfmaq_f32(o, wv, vdupq_n_f32(xv)));
                }
                i += 4;
            }
        }
        while i < w {
            for (r, &xv) in xs.iter().enumerate() {
                oblock[r * w + i] =
                    wrow[i].mul_add(xv, oblock[r * w + i]);
            }
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                           orow: &mut [f32]) -> (f32, f32) {
        let (ks, vs) = match (a.ks, a.vs) {
            (KvView::F32(k), KvView::F32(v)) => (k, v),
            _ => return super::packed::attn_row(a, scores, orow),
        };
        let (hkv, kv, dh) = (a.hkv, a.kv, a.dh);
        let mut mx = f32::NEG_INFINITY;
        for j in 0..a.vis {
            let base = (j * hkv + kv) * dh;
            let s = unsafe { dot8(a.qrow, &ks[base..base + dh]) }
                * a.scale;
            scores[j] = s;
            mx = mx.max(s);
        }
        let mut li = 0f32;
        let mut j = 0;
        // V pass register-blocked by 4 rows; p/li order stays
        // j-ascending
        while j + 4 <= a.vis {
            let mut ps = [0f32; 4];
            for (t, p) in ps.iter_mut().enumerate() {
                *p = (scores[j + t] - mx).exp();
                li += *p;
            }
            let b = [((j) * hkv + kv) * dh,
                     ((j + 1) * hkv + kv) * dh,
                     ((j + 2) * hkv + kv) * dh,
                     ((j + 3) * hkv + kv) * dh];
            unsafe {
                fma_row4(orow,
                         [&vs[b[0]..b[0] + dh], &vs[b[1]..b[1] + dh],
                          &vs[b[2]..b[2] + dh], &vs[b[3]..b[3] + dh]],
                         ps)
            };
            j += 4;
        }
        while j < a.vis {
            let p = (scores[j] - mx).exp();
            li += p;
            let base = (j * hkv + kv) * dh;
            unsafe { fma_row(orow, &vs[base..base + dh], p) };
            j += 1;
        }
        (mx, li)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn router_cell(qrow: &[f32], erow: &[f32], h: usize,
                              dh: usize, group: usize) -> f32 {
        let mut acc = 0f32;
        for hi in 0..h {
            let kv = hi / group;
            acc += unsafe {
                dot8(&qrow[hi * dh..(hi + 1) * dh],
                     &erow[kv * dh..(kv + 1) * dh])
            };
        }
        acc / h as f32
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale2_add(dst: &mut [f32], s1: f32, src: &[f32],
                             s2: f32) {
        let n = dst.len().min(src.len());
        let mut i = 0;
        unsafe {
            let s1v = vdupq_n_f32(s1);
            let s2v = vdupq_n_f32(s2);
            while i + 4 <= n {
                let d = vld1q_f32(dst.as_ptr().add(i));
                let s = vld1q_f32(src.as_ptr().add(i));
                let r = vfmaq_f32(vmulq_f32(d, s1v), s, s2v);
                vst1q_f32(dst.as_mut_ptr().add(i), r);
                i += 4;
            }
        }
        while i < n {
            dst[i] = src[i].mul_add(s2, dst[i] * s1);
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
fn neon_fma_row(orow: &mut [f32], wrow: &[f32], xv: f32) {
    // SAFETY: NEON is mandatory in the aarch64 baseline.
    unsafe { neon::fma_row(orow, wrow, xv) }
}

#[cfg(target_arch = "aarch64")]
fn neon_fma_row4(orow: &mut [f32], wrows: [&[f32]; 4], xs: [f32; 4]) {
    // SAFETY: as above.
    unsafe { neon::fma_row4(orow, wrows, xs) }
}

#[cfg(target_arch = "aarch64")]
fn neon_fma_row_block(oblock: &mut [f32], wrow: &[f32], xs: &[f32]) {
    // SAFETY: as above.
    unsafe { neon::fma_row_block(oblock, wrow, xs) }
}

#[cfg(target_arch = "aarch64")]
fn neon_attn_row(a: &AttnRowArgs<'_>, scores: &mut [f32],
                 orow: &mut [f32]) -> (f32, f32) {
    // SAFETY: as above.
    unsafe { neon::attn_row(a, scores, orow) }
}

#[cfg(target_arch = "aarch64")]
fn neon_router_cell(qrow: &[f32], erow: &[f32], h: usize, dh: usize,
                    group: usize) -> f32 {
    // SAFETY: as above.
    unsafe { neon::router_cell(qrow, erow, h, dh, group) }
}

#[cfg(target_arch = "aarch64")]
fn neon_scale2_add(dst: &mut [f32], s1: f32, src: &[f32], s2: f32) {
    // SAFETY: as above.
    unsafe { neon::scale2_add(dst, s1, src, s2) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{KvDtype, Tensor};
    use crate::util::rng::Rng;

    #[test]
    fn spec_parses() {
        assert_eq!(KernelSpec::parse("auto").unwrap(), KernelSpec::Auto);
        assert_eq!(KernelSpec::parse("").unwrap(), KernelSpec::Auto);
        assert_eq!(KernelSpec::parse("SIMD").unwrap(), KernelSpec::Simd);
        assert_eq!(KernelSpec::parse("scalar").unwrap(),
                   KernelSpec::Scalar);
        assert_eq!(KernelSpec::parse("lanes8").unwrap(),
                   KernelSpec::Lanes8);
        assert_eq!(KernelSpec::parse("avx512").unwrap(),
                   KernelSpec::Avx512);
        assert!(KernelSpec::parse("sse9").is_err());
    }

    #[test]
    fn flavor_tables_resolve() {
        assert_eq!(kernels_for(KernelSpec::Scalar).name, "scalar");
        assert_eq!(kernels_for(KernelSpec::Lanes8).name, "lanes8");
        // Simd = explicit best-detected flavor, independent of env
        let best = kernels_for(KernelSpec::Simd);
        assert!(["avx512", "avx2", "neon", "lanes8"]
            .contains(&best.name));
        // Auto follows the process-global flavor (MOSKA_KERNEL aware),
        // so the ci.sh A/B stages reach the backends through it
        assert!(std::ptr::eq(kernels_for(KernelSpec::Auto),
                             Kernels::global()));
    }

    #[test]
    fn reduce8_order_is_pinned() {
        // values where reduction order changes the f32 result: the
        // pinned tree must give exactly ((l0+l4)+(l2+l6))+((l1+l5)+(l3+l7))
        let l = [1.0e8f32, 1.0, -1.0e8, 3.0, 0.25, -7.0, 2.5e7, 11.0];
        let s0 = l[0] + l[4];
        let s1 = l[1] + l[5];
        let s2 = l[2] + l[6];
        let s3 = l[3] + l[7];
        let want = (s0 + s2) + (s1 + s3);
        assert_eq!(reduce8(&l), want);
    }

    /// The core contract: the best-detected flavor is bit-identical to
    /// the portable `lanes8` flavor on every primitive, across ragged
    /// lengths (tails of every residue mod 8 and mod 16).
    #[test]
    fn simd_flavors_bit_identical_to_lanes8() {
        let a = kernels_for(KernelSpec::Lanes8);
        let b = kernels_for(KernelSpec::Simd); // avx512/avx2/neon/lanes8
        let mut rng = Rng::new(0x51D);
        for len in [1usize, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100] {
            let mut x = vec![0f32; len];
            let mut y = vec![0f32; len];
            rng.fill_normal_f32(&mut x);
            rng.fill_normal_f32(&mut y);

            // fma_row
            let mut oa = x.clone();
            let mut ob = x.clone();
            a.fma_row(&mut oa, &y, 0.37);
            b.fma_row(&mut ob, &y, 0.37);
            assert_eq!(oa, ob, "fma_row len={len} flavor={}", b.name);

            // scale2_add
            let mut da = x.clone();
            let mut db = x.clone();
            a.scale2_add(&mut da, 0.9, &y, 1.7);
            b.scale2_add(&mut db, 0.9, &y, 1.7);
            assert_eq!(da, db, "scale2_add len={len}");

            // div_row
            let mut va = vec![0f32; len];
            let mut vb = vec![0f32; len];
            a.div_row(&mut va, &x, 3.1);
            b.div_row(&mut vb, &x, 3.1);
            assert_eq!(va, vb, "div_row len={len}");

            // fma_row4
            let mut w = vec![vec![0f32; len]; 4];
            for r in w.iter_mut() {
                rng.fill_normal_f32(r);
            }
            let xs = [0.3f32, -1.1, 0.77, 2.5];
            let wr = [w[0].as_slice(), w[1].as_slice(),
                      w[2].as_slice(), w[3].as_slice()];
            let mut qa = x.clone();
            let mut qb = x.clone();
            a.fma_row4(&mut qa, wr, xs);
            b.fma_row4(&mut qb, wr, xs);
            assert_eq!(qa, qb, "fma_row4 len={len}");

            // fma_row_block (3 rows — ragged row count)
            let xs3 = [0.5f32, -0.25, 1.5];
            let mut ba = vec![0.1f32; 3 * len];
            let mut bb = ba.clone();
            a.fma_row_block(&mut ba, &y, &xs3);
            b.fma_row_block(&mut bb, &y, &xs3);
            assert_eq!(ba, bb, "fma_row_block len={len}");
        }

        // attn_row + router_cell over ragged dh and vis
        for &(hkv, dh, c) in
            &[(2usize, 12usize, 5usize), (2, 16, 64), (1, 33, 7)]
        {
            let mut q = vec![0f32; dh];
            let mut ks = vec![0f32; c * hkv * dh];
            let mut vs = vec![0f32; c * hkv * dh];
            rng.fill_normal_f32(&mut q);
            rng.fill_normal_f32(&mut ks);
            rng.fill_normal_f32(&mut vs);
            for vis in [1usize, c / 2 + 1, c] {
                let args = AttnRowArgs {
                    qrow: &q,
                    ks: KvView::F32(&ks),
                    vs: KvView::F32(&vs),
                    kv: hkv - 1,
                    hkv,
                    dh,
                    vis,
                    scale: 1.0 / (dh as f32).sqrt(),
                };
                let mut sa = vec![0f32; c];
                let mut sb = vec![0f32; c];
                let mut oa = vec![0f32; dh];
                let mut ob = vec![0f32; dh];
                let ra = a.attn_row(&args, &mut sa, &mut oa);
                let rb = b.attn_row(&args, &mut sb, &mut ob);
                assert_eq!(ra, rb, "attn_row m/l dh={dh} vis={vis}");
                assert_eq!(oa, ob, "attn_row o dh={dh} vis={vis}");
                assert_eq!(sa[..vis], sb[..vis], "attn_row scores");
            }
            let h = hkv * 2;
            let mut qb = vec![0f32; h * dh];
            let mut eb = vec![0f32; hkv * dh];
            rng.fill_normal_f32(&mut qb);
            rng.fill_normal_f32(&mut eb);
            assert_eq!(a.router_cell(&qb, &eb, h, dh, 2),
                       b.router_cell(&qb, &eb, h, dh, 2),
                       "router_cell dh={dh}");
        }
    }

    /// The scalar flavor keeps the seed bit behavior: multiply-then-add,
    /// sequential reduction.
    #[test]
    fn scalar_flavor_matches_seed_arithmetic() {
        let ks = kernels_for(KernelSpec::Scalar);
        let mut rng = Rng::new(0x5EED);
        let mut x = vec![0f32; 37];
        let mut y = vec![0f32; 37];
        rng.fill_normal_f32(&mut x);
        rng.fill_normal_f32(&mut y);
        let mut got = x.clone();
        ks.fma_row(&mut got, &y, 0.7);
        let want: Vec<f32> =
            x.iter().zip(&y).map(|(o, w)| o + 0.7 * w).collect();
        assert_eq!(got, want);

        let mut qb = vec![0f32; 4 * 9];
        let mut eb = vec![0f32; 2 * 9];
        rng.fill_normal_f32(&mut qb);
        rng.fill_normal_f32(&mut eb);
        let got = ks.router_cell(&qb, &eb, 4, 9, 2);
        let mut acc = 0f32;
        for hi in 0..4 {
            let kv = hi / 2;
            acc += qb[hi * 9..(hi + 1) * 9]
                .iter()
                .zip(&eb[kv * 9..(kv + 1) * 9])
                .map(|(a, b)| a * b)
                .sum::<f32>();
        }
        assert_eq!(got, acc / 4.0);
    }

    /// Register blocks are bit-identical (within each flavor) to the
    /// sequential `fma_row` calls they replace — the proof that
    /// blocking the V pass / matmul rows never changes output.
    #[test]
    fn register_blocks_match_sequential_fma_rows() {
        let mut rng = Rng::new(0xB10C);
        for spec in
            [KernelSpec::Scalar, KernelSpec::Lanes8, KernelSpec::Simd]
        {
            let k = kernels_for(spec);
            for len in [1usize, 7, 16, 33, 64, 100] {
                let mut o0 = vec![0f32; len];
                rng.fill_normal_f32(&mut o0);
                let mut w = vec![vec![0f32; len]; 4];
                for r in w.iter_mut() {
                    rng.fill_normal_f32(r);
                }
                let xs = [1.3f32, -0.4, 0.09, 2.2];
                let wr = [w[0].as_slice(), w[1].as_slice(),
                          w[2].as_slice(), w[3].as_slice()];

                // fma_row4 vs 4 sequential fma_row
                let mut blocked = o0.clone();
                k.fma_row4(&mut blocked, wr, xs);
                let mut seq = o0.clone();
                for (wrow, &xv) in wr.iter().zip(xs.iter()) {
                    k.fma_row(&mut seq, wrow, xv);
                }
                assert_eq!(blocked, seq,
                           "fma_row4 flavor={} len={len}", k.name);

                // fma_row_block vs per-row fma_row
                let xs3 = [0.8f32, -1.6, 0.31];
                let mut blk = vec![0.05f32; 3 * len];
                let mut per = blk.clone();
                k.fma_row_block(&mut blk, &w[0], &xs3);
                for (r, &xv) in xs3.iter().enumerate() {
                    k.fma_row(&mut per[r * len..(r + 1) * len], &w[0],
                              xv);
                }
                assert_eq!(blk, per,
                           "fma_row_block flavor={} len={len}", k.name);
            }
        }
    }

    /// Packed K/V attention is bit-identical across *all* flavors
    /// (scalar included — packed rows all route through one oracle or
    /// a provably-identical AVX2 rebuild), per dtype, over ragged
    /// shapes.
    #[test]
    fn packed_attn_bit_identical_across_flavors() {
        let flavors: Vec<&'static Kernels> =
            [KernelSpec::Scalar, KernelSpec::Lanes8, KernelSpec::Simd]
                .iter()
                .map(|&s| kernels_for(s))
                .collect();
        let mut rng = Rng::new(0xFACC);
        for dt in [KvDtype::F16, KvDtype::Bf16, KvDtype::I8] {
            for &(hkv, dh, c) in
                &[(2usize, 12usize, 5usize), (2, 16, 64), (1, 33, 7)]
            {
                let mut q = vec![0f32; dh];
                let mut ks = vec![0f32; c * hkv * dh];
                let mut vs = vec![0f32; c * hkv * dh];
                rng.fill_normal_f32(&mut q);
                rng.fill_normal_f32(&mut ks);
                rng.fill_normal_f32(&mut vs);
                let kt = Tensor::f32(&[c, hkv, dh], ks).pack_kv(dt);
                let vt = Tensor::f32(&[c, hkv, dh], vs).pack_kv(dt);
                for vis in [1usize, c / 2 + 1, c] {
                    let args = AttnRowArgs {
                        qrow: &q,
                        ks: kt.kv_view(),
                        vs: vt.kv_view(),
                        kv: hkv - 1,
                        hkv,
                        dh,
                        vis,
                        scale: 1.0 / (dh as f32).sqrt(),
                    };
                    let mut ref_s = vec![0f32; c];
                    let mut ref_o = vec![0f32; dh];
                    let ref_ml = flavors[0]
                        .attn_row(&args, &mut ref_s, &mut ref_o);
                    for k in &flavors[1..] {
                        let mut s = vec![0f32; c];
                        let mut o = vec![0f32; dh];
                        let ml = k.attn_row(&args, &mut s, &mut o);
                        assert_eq!(ml, ref_ml,
                                   "packed m/l {dt:?} {} vis={vis}",
                                   k.name);
                        assert_eq!(o, ref_o,
                                   "packed o {dt:?} {} vis={vis}",
                                   k.name);
                        assert_eq!(s[..vis], ref_s[..vis],
                                   "packed scores {dt:?} {}", k.name);
                    }
                }
            }
        }
    }

    /// Packed attention stays close to the f32 reference — the
    /// quantization error bound, not bit-identity (f32 uses libm exp,
    /// packed uses the pinned polynomial).
    #[test]
    fn packed_attn_close_to_f32_reference() {
        let kern = kernels_for(KernelSpec::Lanes8);
        let mut rng = Rng::new(0xC105E);
        let (hkv, dh, c, vis) = (2usize, 16usize, 32usize, 32usize);
        let mut q = vec![0f32; dh];
        let mut ks = vec![0f32; c * hkv * dh];
        let mut vs = vec![0f32; c * hkv * dh];
        rng.fill_normal_f32(&mut q);
        rng.fill_normal_f32(&mut ks);
        rng.fill_normal_f32(&mut vs);
        let kf = Tensor::f32(&[c, hkv, dh], ks);
        let vf = Tensor::f32(&[c, hkv, dh], vs);
        let mut s32 = vec![0f32; c];
        let mut o32 = vec![0f32; dh];
        let args32 = AttnRowArgs {
            qrow: &q,
            ks: kf.kv_view(),
            vs: vf.kv_view(),
            kv: 0,
            hkv,
            dh,
            vis,
            scale: 1.0 / (dh as f32).sqrt(),
        };
        let (m32, l32) = kern.attn_row(&args32, &mut s32, &mut o32);
        for (dt, tol) in [(KvDtype::F16, 2e-3f32),
                          (KvDtype::Bf16, 2e-2),
                          (KvDtype::I8, 4e-2)]
        {
            let kp = kf.pack_kv(dt);
            let vp = vf.pack_kv(dt);
            let argsp = AttnRowArgs {
                qrow: &q,
                ks: kp.kv_view(),
                vs: vp.kv_view(),
                kv: 0,
                hkv,
                dh,
                vis,
                scale: 1.0 / (dh as f32).sqrt(),
            };
            let mut sp = vec![0f32; c];
            let mut op = vec![0f32; dh];
            let (mp, lp) = kern.attn_row(&argsp, &mut sp, &mut op);
            assert!((mp - m32).abs() <= tol * m32.abs().max(1.0),
                    "{dt:?} m {mp} vs {m32}");
            assert!((lp - l32).abs() <= tol * l32.abs().max(1.0),
                    "{dt:?} l {lp} vs {l32}");
            for (a, b) in op.iter().zip(&o32) {
                assert!((a - b).abs() <= tol * b.abs().max(1.0),
                        "{dt:?} o {a} vs {b}");
            }
        }
    }

    /// The pinned-polynomial exp tracks libm exp to ~2 ulp over the
    /// softmax domain (arguments ≤ 0).
    #[test]
    fn exp_pinned_close_to_libm() {
        let mut x = -87.0f32;
        while x <= 0.0 {
            let got = pexp::exp_pinned(x);
            let want = x.exp();
            let rel = (got - want).abs() / want.max(f32::MIN_POSITIVE);
            assert!(rel <= 1e-6, "exp_pinned({x}) = {got} vs {want}");
            x += 0.0437;
        }
        assert_eq!(pexp::exp_pinned(0.0), 1.0);
        // clamped tails stay finite and positive
        assert!(pexp::exp_pinned(-1.0e9) > 0.0);
        assert!(pexp::exp_pinned(1.0e9).is_finite());
    }

    /// The AVX2 8-lane exp mirrors the scalar pinned exp bit for bit.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_exp8_bit_identical_to_exp_pinned() {
        if !(std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma"))
        {
            return;
        }
        use std::arch::x86_64::*;
        let xs: [f32; 8] =
            [0.0, -0.5, -1.25, -7.75, -20.0, -86.9, -0.001, -13.37];
        let mut got = [0f32; 8];
        // SAFETY: detection checked above.
        unsafe {
            let v = _mm256_loadu_ps(xs.as_ptr());
            _mm256_storeu_ps(got.as_mut_ptr(), avx2::exp8(v));
        }
        for (x, g) in xs.iter().zip(&got) {
            assert_eq!(g.to_bits(), pexp::exp_pinned(*x).to_bits(),
                       "exp8({x})");
        }
    }

    /// The AVX-512 flavor's element-wise ops are bit-identical to
    /// lanes8 (hence avx2) — the flavor changes vector width only.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx512_elementwise_bit_identical_to_lanes8() {
        if !avx512_supported() {
            return;
        }
        let a = kernels_for(KernelSpec::Lanes8);
        let b = kernels_for(KernelSpec::Avx512);
        assert_eq!(b.name, "avx512");
        let mut rng = Rng::new(0x512);
        for len in [1usize, 7, 15, 16, 17, 31, 32, 33, 100] {
            let mut x = vec![0f32; len];
            let mut y = vec![0f32; len];
            rng.fill_normal_f32(&mut x);
            rng.fill_normal_f32(&mut y);
            let mut oa = x.clone();
            let mut ob = x.clone();
            a.fma_row(&mut oa, &y, -0.83);
            b.fma_row(&mut ob, &y, -0.83);
            assert_eq!(oa, ob, "avx512 fma_row len={len}");
            let mut da = x.clone();
            let mut db = x.clone();
            a.scale2_add(&mut da, 1.1, &y, -0.6);
            b.scale2_add(&mut db, 1.1, &y, -0.6);
            assert_eq!(da, db, "avx512 scale2_add len={len}");
        }
    }
}
