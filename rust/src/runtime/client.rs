//! PJRT client wrapper with a compiled-executable cache.
//!
//! `XlaRuntime` owns the CPU PJRT client and lazily compiles each HLO
//! artifact on first use; serving steady state always hits the cache.
//! PJRT handles are `Rc`-based (not `Send`), so a process gets one
//! [`RuntimeService`] thread per simulated device that owns the runtime,
//! and the rest of the coordinator talks to it through the cloneable,
//! thread-safe [`RuntimeHandle`] — the same shape as a real GPU executor
//! thread fed by a submission queue.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::metrics::Metrics;
use crate::runtime::artifact::Manifest;
use crate::runtime::literal::{from_literal, to_literal};
use crate::tensor::Tensor;

/// Single-thread PJRT runtime (not `Send`; see [`RuntimeService`]).
pub struct XlaRuntime {
    pub manifest: Arc<Manifest>,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    pub metrics: Arc<Metrics>,
}

impl XlaRuntime {
    /// Open the artifacts dir and start a CPU PJRT client.
    pub fn load(artifacts_dir: &str) -> Result<XlaRuntime> {
        let manifest = Arc::new(Manifest::load(artifacts_dir)?);
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        crate::info!(
            "runtime",
            "PJRT platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifact_count()
        );
        Ok(XlaRuntime {
            manifest,
            client,
            executables: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Compile (or fetch cached) executable for `name`.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self.manifest.meta(name)?;
            let path = self.manifest.hlo_path(meta);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            crate::debug!("runtime", "compiled {name} in {:?}", t0.elapsed());
            self.metrics.count("artifact_compiles", 1);
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Eagerly compile every artifact (avoids first-request latency).
    pub fn warmup(&mut self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.artifact_names().cloned().collect();
        let t0 = Instant::now();
        for n in &names {
            self.executable(n)?;
        }
        crate::info!("runtime", "warmed {} artifacts in {:?}",
                     names.len(), t0.elapsed());
        Ok(())
    }

    /// Execute artifact `name` with `inputs`; returns the output tensors.
    ///
    /// Inputs must match the manifest shapes exactly (bucket padding is the
    /// caller's job — see [`backend::XlaBackend`][super::backend::XlaBackend]).
    pub fn execute(&mut self, name: &str, inputs: &[Tensor])
                   -> Result<Vec<Tensor>> {
        let manifest = Arc::clone(&self.manifest);
        let meta = manifest.meta(name)?;
        meta.check_inputs(inputs)
            .with_context(|| format!("executing '{name}'"))?;
        let metrics = Arc::clone(&self.metrics);
        let exe = self.executable(name)?;

        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("PJRT execute '{name}'"))?;
        let root = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        metrics.count("pjrt_executions", 1);
        metrics.observe_ns("pjrt_execute_ns", t0.elapsed().as_nanos() as u64);

        // aot.py lowers with return_tuple=True: root is always a tuple.
        let parts = root.to_tuple().context("decompose result tuple")?;
        if parts.len() != meta.outputs.len() {
            anyhow::bail!("'{name}': {} outputs, manifest says {}",
                          parts.len(), meta.outputs.len());
        }
        parts
            .iter()
            .zip(&meta.outputs)
            .map(|(lit, port)| from_literal(lit, &port.shape, port.dtype))
            .collect()
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }
}

// ------------------------------------------------------------- service

enum Req {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        reply: Sender<Result<Vec<Tensor>>>,
    },
    Warmup {
        reply: Sender<Result<()>>,
    },
    Shutdown,
}

/// Owns an [`XlaRuntime`] on a dedicated thread; dropped = thread joins.
pub struct RuntimeService {
    handle: RuntimeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Cloneable, `Send + Sync` submission handle to a [`RuntimeService`].
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Arc<Mutex<Sender<Req>>>,
    pub manifest: Arc<Manifest>,
    pub metrics: Arc<Metrics>,
}

impl RuntimeService {
    /// Load artifacts and spin up the executor thread.
    pub fn spawn(artifacts_dir: &str) -> Result<RuntimeService> {
        let (tx, rx) = channel::<Req>();
        let (init_tx, init_rx) =
            channel::<Result<(Arc<Manifest>, Arc<Metrics>)>>();
        let dir = artifacts_dir.to_string();
        let join = std::thread::Builder::new()
            .name("moska-pjrt".into())
            .spawn(move || {
                let mut rt = match XlaRuntime::load(&dir) {
                    Ok(rt) => {
                        let _ = init_tx.send(Ok((
                            Arc::clone(&rt.manifest),
                            Arc::clone(&rt.metrics),
                        )));
                        rt
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Execute { name, inputs, reply } => {
                            let _ = reply.send(rt.execute(&name, &inputs));
                        }
                        Req::Warmup { reply } => {
                            let _ = reply.send(rt.warmup());
                        }
                        Req::Shutdown => break,
                    }
                }
            })
            .context("spawn pjrt thread")?;
        let (manifest, metrics) = init_rx
            .recv()
            .context("pjrt thread died during init")??;
        Ok(RuntimeService {
            handle: RuntimeHandle { tx: Arc::new(Mutex::new(tx)), manifest, metrics },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.handle.tx.lock().unwrap().send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl RuntimeHandle {
    /// Execute an artifact on the runtime thread; blocks for the result.
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>)
                   -> Result<Vec<Tensor>> {
        let (reply, rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Req::Execute { name: name.to_string(), inputs, reply })
            .map_err(|_| anyhow::anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("runtime thread dropped reply"))?
    }

    /// Compile every artifact now.
    pub fn warmup(&self) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Req::Warmup { reply })
            .map_err(|_| anyhow::anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("runtime thread dropped reply"))?
    }
}
