//! Pure-rust implementations of every model op.
//!
//! Mirrors `python/compile/model.py` + the Pallas kernels exactly (same
//! math, same conventions). Three roles:
//!
//! 1. **Fallback backend** — the engine runs end-to-end without artifacts
//!    (e.g. fresh checkout, analytical-only usage).
//! 2. **Test oracle** — integration tests compare XLA artifact outputs to
//!    these on random inputs, independent of the python goldens.
//! 3. **Baseline** — the `gemm_vs_gemv` bench uses the scalar loops here
//!    as the unbatched reference point.
//!
//! Layouts match the artifacts: row-major `[B, H, dh]` queries,
//! `[C, Hkv, dh]` chunk K/V, GQA head `h` reads KV head `h / group`.
//!
//! ## Parallel execution layer
//!
//! Every hot kernel comes in two forms: the plain function (serial, the
//! reference) and an `*_exec` twin taking `Option<&ThreadPool>`.
//! [`NativeBackend`][crate::runtime::NativeBackend] passes its pool so the
//! decode hot path fans out over tiles:
//!
//! * [`matmul_exec`] — row blocks when the batch is deep, column blocks
//!   when it is shallow, each over a cache-tiled dense microkernel;
//! * [`chunk_attn_exec`] — contiguous `(query-row, head)` tile spans;
//! * [`router_score_exec`] — contiguous `(row, chunk)` cell spans.
//!
//! **Determinism contract:** a tile owns a disjoint `&mut` slice of the
//! output and runs the *same* per-element floating-point reduction order
//! as the serial loop — there are no cross-thread reductions — so the
//! parallel result is bit-identical to the scalar reference for every
//! shape and thread count (asserted by `parallel_kernels_bit_identical`).
//! Per-worker scratch (attention score rows, the shallow-matmul column
//! blocks) lives in thread-local buffers, so the steady-state decode
//! step allocates near-zero beyond the output tensors themselves.
//!
//! ## SIMD microkernel layer
//!
//! The primitive inner ops of every hot loop — matmul column updates,
//! the per-row chunk-attention body (QK^T, online softmax, V
//! accumulation), router score cells, and the LSE-merge/finalize tails —
//! dispatch through a [`Kernels`] vtable
//! ([`runtime::simd`][crate::runtime::simd]): runtime-detected AVX-512 /
//! AVX2 / NEON / portable-8-lane flavors, plus the seed `scalar` flavor
//! which preserves the pre-SIMD arithmetic bit-for-bit. Tiling, work
//! splitting, and the parallel contract above are flavor-independent
//! and live here; only the per-stripe arithmetic is dispatched. The
//! `*_exec` twins take the vtable explicitly (backends pass their own);
//! the plain wrappers use the process-global [`Kernels::global`]
//! flavor (`MOSKA_KERNEL` env).
//!
//! Chunk-attention K/V arrives as dtype-tagged
//! [`KvView`][crate::tensor::KvView]s: packed (f16/bf16/int8) shared or
//! paged K/V is widened to f32 *inside* the flavor's `attn_row` body —
//! no separate dequant pass — while f32 K/V takes the unchanged seed
//! paths. The matmul microkernels additionally register-block four
//! output rows per weight-row load ([`Kernels::fma_row_block`]), which
//! preserves per-element `k`-order and therefore bit output in every
//! flavor.

use std::cell::RefCell;

use crate::config::ModelConfig;
use crate::runtime::simd::{AttnRowArgs, Kernels};
use crate::tensor::{KvView, Tensor};
use crate::util::threadpool::ThreadPool;

/// Below this much work (inner-loop MAC count) a kernel stays serial:
/// fork-join dispatch costs a few µs per tile and would swamp tiny calls.
/// Public so coordinator-level fan-outs (the engine's per-request
/// unique-attention jobs) can apply the same floor.
pub const PAR_MIN_WORK: usize = 1 << 14;

/// Fork-join tiles per worker — enough slack for load balancing without
/// descending into dispatch-bound tile sizes.
const TILES_PER_WORKER: usize = 4;

/// `w` rows per microkernel tile: bounds the live slab of `w` a tile
/// streams (`MM_K_TILE × n` floats) so it stays cache-resident across the
/// row loop. Accumulation order per output element is still strictly
/// ascending in `k`, preserving bit-exactness.
const MM_K_TILE: usize = 64;

thread_local! {
    /// Per-worker attention score scratch, reused across kernel calls.
    static ATTN_SCORES: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Caller-side column-block staging for the shallow-batch matmul
    /// path (one flat `[b, n]` slab split into disjoint per-tile
    /// chunks), reused across calls so the steady-state decode step
    /// allocates nothing here either.
    static MM_COL_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Attention partials (unnormalized): o `[B,H,dh]`, m `[B,H]`, l `[B,H]`.
/// `PartialEq` compares raw tensor payloads — the codec-roundtrip
/// bit-identity surface (note `-inf == -inf` holds; NaN does not).
#[derive(Debug, Clone, PartialEq)]
pub struct Partials {
    pub o: Tensor,
    pub m: Tensor,
    pub l: Tensor,
}

impl Partials {
    /// The LSE-merge identity: (0, -inf, 0) — what fully-masked rows emit.
    pub fn identity(b: usize, h: usize, dh: usize) -> Partials {
        Partials {
            o: Tensor::zeros_f32(&[b, h, dh]),
            m: Tensor::f32(&[b, h], vec![f32::NEG_INFINITY; b * h]),
            l: Tensor::zeros_f32(&[b, h]),
        }
    }

    pub fn batch(&self) -> usize {
        self.o.shape()[0]
    }
}

/// `x[B,d] @ w[d,n] → [B,n]` (serial reference; see [`matmul_exec`]).
pub fn matmul(x: &Tensor, w: &Tensor) -> Tensor {
    matmul_exec(x, w, None)
}

/// Dense cache-tiled microkernel: rows `[r0, r0+rows)` of `x @ w` into
/// `orows` (row-local indexing). Rows go through the flavor's
/// register-blocked [`Kernels::fma_row_block`] four at a time (one `w`
/// row load feeds four output rows); the ragged remainder uses the
/// per-row [`Kernels::fma_row`]. `k` still ascends per output element
/// and each element receives exactly one fused update per `k`, so any
/// row partitioning — and the blocking itself — reproduces the serial
/// result bit-for-bit in every flavor.
fn mm_rows(kern: &Kernels, xs: &[f32], ws: &[f32], orows: &mut [f32],
           r0: usize, d: usize, n: usize) {
    let rows = orows.len() / n;
    let mut k0 = 0;
    while k0 < d {
        let k1 = (k0 + MM_K_TILE).min(d);
        let mut i = 0;
        while i + 4 <= rows {
            let oblock = &mut orows[i * n..(i + 4) * n];
            for kk in k0..k1 {
                let xv = [xs[(r0 + i) * d + kk],
                          xs[(r0 + i + 1) * d + kk],
                          xs[(r0 + i + 2) * d + kk],
                          xs[(r0 + i + 3) * d + kk]];
                let wrow = &ws[kk * n..(kk + 1) * n];
                kern.fma_row_block(oblock, wrow, &xv);
            }
            i += 4;
        }
        while i < rows {
            let xrow = &xs[(r0 + i) * d..(r0 + i + 1) * d];
            let orow = &mut orows[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let xv = xrow[kk];
                let wrow = &ws[kk * n..(kk + 1) * n];
                kern.fma_row(orow, wrow, xv);
            }
            i += 1;
        }
        k0 = k1;
    }
}

/// Column-block microkernel for shallow batches: columns `[c0, c0+width)`
/// of every row into `oblock` (`[b, width]`, block-local indexing).
/// Same 4-row register blocking as [`mm_rows`] (rarely hit: this path
/// serves shallow batches), same bit-exactness argument.
fn mm_cols(kern: &Kernels, xs: &[f32], ws: &[f32], oblock: &mut [f32],
           b: usize, d: usize, n: usize, c0: usize) {
    let width = oblock.len() / b;
    let mut i = 0;
    while i + 4 <= b {
        let ob = &mut oblock[i * width..(i + 4) * width];
        for kk in 0..d {
            let wrow = &ws[kk * n + c0..kk * n + c0 + width];
            let xv = [xs[i * d + kk], xs[(i + 1) * d + kk],
                      xs[(i + 2) * d + kk], xs[(i + 3) * d + kk]];
            kern.fma_row_block(ob, wrow, &xv);
        }
        i += 4;
    }
    while i < b {
        let xrow = &xs[i * d..(i + 1) * d];
        let orow = &mut oblock[i * width..(i + 1) * width];
        for (kk, &xv) in xrow.iter().enumerate() {
            let wrow = &ws[kk * n + c0..kk * n + c0 + width];
            kern.fma_row(orow, wrow, xv);
        }
        i += 1;
    }
}

/// [`matmul_exec`] with the process-global kernel flavor.
pub fn matmul_exec(x: &Tensor, w: &Tensor, pool: Option<&ThreadPool>)
                   -> Tensor {
    matmul_exec_kern(x, w, pool, Kernels::global())
}

/// `x[B,d] @ w[d,n] → [B,n]`, fanned out over the pool when one is given
/// and the call is big enough to amortize dispatch. Deep batches split
/// into row blocks (zero-copy scatter via `chunks_mut`); shallow ones
/// split into column blocks staged in a thread-local slab (no per-call
/// allocation) and assembled after the join. Both keep the serial
/// per-element reduction order → bit-identical output per flavor.
pub fn matmul_exec_kern(x: &Tensor, w: &Tensor, pool: Option<&ThreadPool>,
                        kern: &Kernels) -> Tensor {
    let (b, d) = (x.shape()[0], x.shape()[1]);
    let (wd, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(d, wd, "matmul inner dim: {d} vs {wd}");
    let xs = x.as_f32();
    let ws = w.as_f32();
    let mut out = vec![0f32; b * n];
    let pool = pool.filter(|p| {
        p.threads() > 1 && b * d * n >= PAR_MIN_WORK
            && !ThreadPool::on_worker_thread()
    });
    match pool {
        Some(p) if b >= p.threads() => {
            // deep batch: contiguous row blocks
            let pieces = (p.threads() * TILES_PER_WORKER).min(b);
            let span = b.div_ceil(pieces);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(b.div_ceil(span));
            for (ti, orows) in out.chunks_mut(span * n).enumerate() {
                jobs.push(Box::new(move || {
                    mm_rows(kern, xs, ws, orows, ti * span, d, n);
                }));
            }
            p.scoped_run(jobs);
        }
        Some(p) => {
            // shallow batch: column blocks carved out of one recycled
            // thread-local slab (workers write disjoint chunks; only
            // this caller thread touches the RefCell)
            let pieces = (p.threads() * TILES_PER_WORKER).min(n);
            let span = n.div_ceil(pieces);
            MM_COL_SCRATCH.with(|cell| {
                let mut scratch = cell.borrow_mut();
                scratch.clear();
                scratch.resize(b * n, 0.0);
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(n.div_ceil(span));
                for (ti, oblock) in
                    scratch.chunks_mut(b * span).enumerate()
                {
                    jobs.push(Box::new(move || {
                        mm_cols(kern, xs, ws, oblock, b, d, n, ti * span);
                    }));
                }
                p.scoped_run(jobs);
                for (ti, oblock) in scratch.chunks(b * span).enumerate() {
                    let (c0, width) = (ti * span, oblock.len() / b);
                    for i in 0..b {
                        out[i * n + c0..i * n + c0 + width]
                            .copy_from_slice(
                                &oblock[i * width..(i + 1) * width],
                            );
                    }
                }
            });
        }
        None => mm_rows(kern, xs, ws, &mut out, 0, d, n),
    }
    Tensor::f32(&[b, n], out)
}

/// RMSNorm over the last axis of a rank-2 tensor.
pub fn rms_norm(x: &Tensor, w: &Tensor, eps: f64) -> Tensor {
    let (b, d) = (x.shape()[0], x.shape()[1]);
    assert_eq!(w.shape(), &[d]);
    let xs = x.as_f32();
    let ws = w.as_f32();
    let mut out = vec![0f32; b * d];
    for i in 0..b {
        let row = &xs[i * d..(i + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let scale = 1.0 / (ms + eps as f32).sqrt();
        for j in 0..d {
            out[i * d + j] = row[j] * scale * ws[j];
        }
    }
    Tensor::f32(&[b, d], out)
}

/// RoPE inverse-frequency table: `freq[j] = theta^(-j/half)` for
/// `j < half = dh/2`. Compute once per model (it only depends on the
/// architecture) and reuse via [`rope_with`] — the old per-element
/// `powf` was ~30 transcendental ops per rotated pair.
pub fn rope_inv_freq(dh: usize, theta: f64) -> Vec<f64> {
    let half = dh / 2;
    (0..half)
        .map(|j| theta.powf(-(j as f64) / half as f64))
        .collect()
}

/// RoPE (half-split) with a precomputed [`rope_inv_freq`] table.
pub fn rope_with(x: &mut Tensor, pos: &[i32], freqs: &[f64]) {
    let shape = x.shape().to_vec();
    let (b, n, dh) = (shape[0], shape[1], shape[2]);
    assert_eq!(pos.len(), b);
    let half = dh / 2;
    assert_eq!(freqs.len(), half, "rope freq table length");
    let xs = x.as_f32_mut();
    for i in 0..b {
        let p = pos[i] as f64;
        for h in 0..n {
            let base = (i * n + h) * dh;
            for (j, &freq) in freqs.iter().enumerate() {
                let ang = p * freq;
                let (sin, cos) = (ang.sin() as f32, ang.cos() as f32);
                let x1 = xs[base + j];
                let x2 = xs[base + half + j];
                xs[base + j] = x1 * cos - x2 * sin;
                xs[base + half + j] = x2 * cos + x1 * sin;
            }
        }
    }
}

/// RoPE (half-split), matching `model.rope`: x `[B, n, dh]`, pos `[B]`.
pub fn rope(x: &mut Tensor, pos: &[i32], theta: f64) {
    let freqs = rope_inv_freq(x.shape()[2], theta);
    rope_with(x, pos, &freqs);
}

/// Token embedding: tokens i32`[B]` × emb `[V,d]` → `[B,d]`.
pub fn embed(tokens: &Tensor, emb: &Tensor) -> Tensor {
    let b = tokens.shape()[0];
    let (v, d) = (emb.shape()[0], emb.shape()[1]);
    let es = emb.as_f32();
    let mut out = vec![0f32; b * d];
    for (i, &t) in tokens.as_i32().iter().enumerate() {
        let t = t as usize;
        assert!(t < v, "token {t} out of vocab {v}");
        out[i * d..(i + 1) * d].copy_from_slice(&es[t * d..(t + 1) * d]);
    }
    Tensor::f32(&[b, d], out)
}

/// Pre-norm + QKV projection + RoPE (artifact `qkv_b*`).
pub fn qkv(cfg: &ModelConfig, x: &Tensor, attn_norm: &Tensor, wq: &Tensor,
           wk: &Tensor, wv: &Tensor, pos: &[i32])
           -> (Tensor, Tensor, Tensor) {
    qkv_exec(cfg, x, attn_norm, wq, wk, wv, pos, None, None,
             Kernels::global())
}

/// [`qkv`] with an optional execution pool, precomputed RoPE table, and
/// kernel flavor.
#[allow(clippy::too_many_arguments)]
pub fn qkv_exec(cfg: &ModelConfig, x: &Tensor, attn_norm: &Tensor,
                wq: &Tensor, wk: &Tensor, wv: &Tensor, pos: &[i32],
                freqs: Option<&[f64]>, pool: Option<&ThreadPool>,
                kern: &Kernels) -> (Tensor, Tensor, Tensor) {
    let b = x.shape()[0];
    let xn = rms_norm(x, attn_norm, cfg.rms_eps);
    let mut q = matmul_exec_kern(&xn, wq, pool, kern)
        .reshaped(&[b, cfg.n_heads, cfg.head_dim]);
    let mut k = matmul_exec_kern(&xn, wk, pool, kern)
        .reshaped(&[b, cfg.n_kv_heads, cfg.head_dim]);
    let v = matmul_exec_kern(&xn, wv, pool, kern)
        .reshaped(&[b, cfg.n_kv_heads, cfg.head_dim]);
    match freqs {
        Some(f) => {
            rope_with(&mut q, pos, f);
            rope_with(&mut k, pos, f);
        }
        None => {
            let f = rope_inv_freq(cfg.head_dim, cfg.rope_theta);
            rope_with(&mut q, pos, &f);
            rope_with(&mut k, pos, &f);
        }
    }
    (q, k, v)
}

/// Shared-KV chunk attention (mirrors the Pallas kernel bit-for-bit in
/// convention): q `[B,H,dh]`, k/v `[C,Hkv,dh]`, per-query positions,
/// chunk base position, valid length. Returns unnormalized partials.
pub fn chunk_attn(q: &Tensor, k: &Tensor, v: &Tensor, q_pos: &[i32],
                  k_base: i32, valid: i32) -> Partials {
    chunk_attn_exec_kern(q, k, v, q_pos, k_base, valid, None,
                         Kernels::global())
}

/// Worker for one contiguous span of flattened `(query-row, head)` rows
/// `[r0, r0+rows)`: `o`/`m`/`l` are the span's disjoint output slices
/// (span-local indexing), pre-filled with the LSE identity. Score rows
/// use the per-worker thread-local scratch; the per-row arithmetic runs
/// on the flavor's [`Kernels::attn_row`] body, so the reduction order
/// is exactly the serial kernel's for the same flavor.
#[allow(clippy::too_many_arguments)]
fn chunk_attn_rows(kern: &Kernels, qs: &[f32], ks: KvView<'_>,
                   vs: KvView<'_>, q_pos: &[i32], k_base: i32,
                   valid: i32, h: usize, dh: usize, hkv: usize,
                   c: usize, r0: usize, o: &mut [f32], m: &mut [f32],
                   l: &mut [f32]) {
    let group = h / hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let rows = m.len();
    ATTN_SCORES.with(|cell| {
        let mut scores = cell.borrow_mut();
        scores.resize(c, 0.0);
        for r in 0..rows {
            let (bi, hi) = ((r0 + r) / h, (r0 + r) % h);
            let qp = q_pos[bi];
            if qp < 0 {
                continue; // padding row: identity partial
            }
            // visible key range within the chunk (keys are positionally
            // contiguous: key j has absolute position k_base + j)
            let vis = ((qp - k_base + 1).clamp(0, valid)) as usize;
            if vis == 0 {
                continue;
            }
            let kv = hi / group;
            let qrow = &qs[(bi * h + hi) * dh..(bi * h + hi + 1) * dh];
            let args = AttnRowArgs {
                qrow, ks, vs, kv, hkv, dh, vis, scale,
            };
            let orow = &mut o[r * dh..(r + 1) * dh];
            let (mx, li) = kern.attn_row(&args, &mut scores[..], orow);
            m[r] = mx;
            l[r] = li;
        }
    });
}

/// [`chunk_attn_exec_kern`] with the process-global kernel flavor.
pub fn chunk_attn_exec(q: &Tensor, k: &Tensor, v: &Tensor, q_pos: &[i32],
                       k_base: i32, valid: i32, pool: Option<&ThreadPool>)
                       -> Partials {
    chunk_attn_exec_kern(q, k, v, q_pos, k_base, valid, pool,
                         Kernels::global())
}

/// [`chunk_attn`] fanned out over `(query-row, head)` tile spans when a
/// pool is given and the call is big enough. Bit-identical to serial.
#[allow(clippy::too_many_arguments)]
pub fn chunk_attn_exec_kern(q: &Tensor, k: &Tensor, v: &Tensor,
                            q_pos: &[i32], k_base: i32, valid: i32,
                            pool: Option<&ThreadPool>, kern: &Kernels)
                            -> Partials {
    let (b, h, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let mut o = vec![0f32; b * h * dh];
    let mut m = vec![f32::NEG_INFINITY; b * h];
    let mut l = vec![0f32; b * h];
    chunk_attn_slices(kern, q, k, v, q_pos, k_base, valid, pool, &mut o,
                      &mut m, &mut l);
    Partials {
        o: Tensor::f32(&[b, h, dh], o),
        m: Tensor::f32(&[b, h], m),
        l: Tensor::f32(&[b, h], l),
    }
}

/// [`chunk_attn_exec_into_kern`] with the process-global kernel flavor.
#[allow(clippy::too_many_arguments)]
pub fn chunk_attn_exec_into(q: &Tensor, k: &Tensor, v: &Tensor,
                            q_pos: &[i32], k_base: i32, valid: i32,
                            pool: Option<&ThreadPool>, out: &mut Partials) {
    chunk_attn_exec_into_kern(q, k, v, q_pos, k_base, valid, pool,
                              Kernels::global(), out)
}

/// [`chunk_attn_exec_kern`] into caller-owned (arena) partials. `out`
/// must be identity-filled (`o = 0`, `m = -inf`, `l = 0`) — masked rows
/// are left untouched, exactly like the allocating variant's initial
/// fill.
#[allow(clippy::too_many_arguments)]
pub fn chunk_attn_exec_into_kern(q: &Tensor, k: &Tensor, v: &Tensor,
                                 q_pos: &[i32], k_base: i32, valid: i32,
                                 pool: Option<&ThreadPool>, kern: &Kernels,
                                 out: &mut Partials) {
    let (b, h, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    debug_assert_eq!(out.o.shape(), &[b, h, dh]);
    chunk_attn_slices(kern, q, k, v, q_pos, k_base, valid, pool,
                      out.o.as_f32_mut(), out.m.as_f32_mut(),
                      out.l.as_f32_mut());
}

/// Shared worker behind both `chunk_attn_exec` variants: `o`/`m`/`l`
/// must arrive identity-filled; tiling and reduction order are identical
/// regardless of where the output storage came from.
#[allow(clippy::too_many_arguments)]
fn chunk_attn_slices(kern: &Kernels, q: &Tensor, k: &Tensor, v: &Tensor,
                     q_pos: &[i32], k_base: i32, valid: i32,
                     pool: Option<&ThreadPool>, o: &mut [f32],
                     m: &mut [f32], l: &mut [f32]) {
    let (b, h, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let (c, hkv, _) = (k.shape()[0], k.shape()[1], k.shape()[2]);
    let qs = q.as_f32();
    // K/V may be packed (f16/bf16/int8): hand the kernels dtype-tagged
    // views and let each flavor widen rows in-register. `KvView` is
    // `Copy`, so the fork-join job closures capture it by value.
    let ks = k.kv_view();
    let vs = v.kv_view();

    let rows = b * h;
    let work = rows * valid.max(0) as usize * dh;
    let pool = pool.filter(|p| {
        p.threads() > 1 && rows > 1 && work >= PAR_MIN_WORK
            && !ThreadPool::on_worker_thread()
    });
    match pool {
        Some(p) => {
            let pieces = (p.threads() * TILES_PER_WORKER).min(rows);
            let span = rows.div_ceil(pieces);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(rows.div_ceil(span));
            for ((ti, oc), (mc, lc)) in o
                .chunks_mut(span * dh)
                .enumerate()
                .zip(m.chunks_mut(span).zip(l.chunks_mut(span)))
            {
                jobs.push(Box::new(move || {
                    chunk_attn_rows(kern, qs, ks, vs, q_pos, k_base, valid,
                                    h, dh, hkv, c, ti * span, oc, mc, lc);
                }));
            }
            p.scoped_run(jobs);
        }
        None => chunk_attn_rows(kern, qs, ks, vs, q_pos, k_base, valid, h,
                                dh, hkv, c, 0, o, m, l),
    }
}

/// Attention out-proj + residual + SwiGLU FFN (artifact `post_b*`).
/// `attn_o` must already be normalized (merged partials / l).
pub fn post(cfg: &ModelConfig, attn_o: &Tensor, x: &Tensor, wo: &Tensor,
            ffn_norm: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor)
            -> Tensor {
    post_exec(cfg, attn_o, x, wo, ffn_norm, w1, w3, w2, None,
              Kernels::global())
}

/// [`post`] with the projection/FFN matmuls on the pool.
#[allow(clippy::too_many_arguments)]
pub fn post_exec(cfg: &ModelConfig, attn_o: &Tensor, x: &Tensor,
                 wo: &Tensor, ffn_norm: &Tensor, w1: &Tensor, w3: &Tensor,
                 w2: &Tensor, pool: Option<&ThreadPool>, kern: &Kernels)
                 -> Tensor {
    let b = x.shape()[0];
    let flat = attn_o.clone().reshaped(&[b, cfg.q_dim()]);
    let proj = matmul_exec_kern(&flat, wo, pool, kern);
    let mut h = vec![0f32; b * cfg.d_model];
    for (i, (xv, pv)) in x.as_f32().iter().zip(proj.as_f32()).enumerate() {
        h[i] = xv + pv;
    }
    let h = Tensor::f32(&[b, cfg.d_model], h);
    let hn = rms_norm(&h, ffn_norm, cfg.rms_eps);
    let a = matmul_exec_kern(&hn, w1, pool, kern);
    let g = matmul_exec_kern(&hn, w3, pool, kern);
    let mut act = vec![0f32; b * cfg.ffn_dim];
    for (i, (&av, &gv)) in a.as_f32().iter().zip(g.as_f32()).enumerate() {
        // silu(a) * g
        let s = av / (1.0 + (-av).exp());
        act[i] = s * gv;
    }
    let ffn = matmul_exec_kern(&Tensor::f32(&[b, cfg.ffn_dim], act), w2,
                               pool, kern);
    let mut out = vec![0f32; b * cfg.d_model];
    for (i, (hv, fv)) in h.as_f32().iter().zip(ffn.as_f32()).enumerate() {
        out[i] = hv + fv;
    }
    Tensor::f32(&[b, cfg.d_model], out)
}

/// Final norm + LM head (artifact `lm_head_b*`).
pub fn lm_head(cfg: &ModelConfig, x: &Tensor, final_norm: &Tensor,
               w_lm: &Tensor) -> Tensor {
    lm_head_exec(cfg, x, final_norm, w_lm, None, Kernels::global())
}

/// [`lm_head`] with the vocab projection on the pool.
pub fn lm_head_exec(cfg: &ModelConfig, x: &Tensor, final_norm: &Tensor,
                    w_lm: &Tensor, pool: Option<&ThreadPool>,
                    kern: &Kernels) -> Tensor {
    matmul_exec_kern(&rms_norm(x, final_norm, cfg.rms_eps), w_lm, pool,
                     kern)
}

/// Router scoring (artifact `router_b*_c*`): mean over query heads of
/// `q_h · emb_{c, kv(h)}`.
pub fn router_score(q: &Tensor, embs: &Tensor) -> Tensor {
    router_score_exec_kern(q, embs, None, Kernels::global())
}

/// Worker for one contiguous span of flattened `(row, chunk)` score
/// cells `[r0, r0+out.len())` (span-local indexing in `out`).
#[allow(clippy::too_many_arguments)]
fn router_cells(kern: &Kernels, qs: &[f32], es: &[f32], h: usize,
                dh: usize, hkv: usize, c: usize, r0: usize,
                out: &mut [f32]) {
    let group = h / hkv;
    for (idx, slot) in out.iter_mut().enumerate() {
        let (bi, ci) = ((r0 + idx) / c, (r0 + idx) % c);
        let qrow = &qs[bi * h * dh..(bi + 1) * h * dh];
        let erow = &es[ci * hkv * dh..(ci + 1) * hkv * dh];
        *slot = kern.router_cell(qrow, erow, h, dh, group);
    }
}

/// [`router_score_exec_kern`] with the process-global kernel flavor.
pub fn router_score_exec(q: &Tensor, embs: &Tensor,
                         pool: Option<&ThreadPool>) -> Tensor {
    router_score_exec_kern(q, embs, pool, Kernels::global())
}

/// [`router_score`] fanned out over `(row, chunk)` cell spans when a pool
/// is given and the score matrix is big enough. Bit-identical to serial.
pub fn router_score_exec_kern(q: &Tensor, embs: &Tensor,
                              pool: Option<&ThreadPool>, kern: &Kernels)
                              -> Tensor {
    let (b, h, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let (c, hkv, _) = (embs.shape()[0], embs.shape()[1], embs.shape()[2]);
    let qs = q.as_f32();
    let es = embs.as_f32();
    let mut out = vec![0f32; b * c];
    let cells = b * c;
    let pool = pool.filter(|p| {
        p.threads() > 1 && cells > 1 && cells * h * dh >= PAR_MIN_WORK
            && !ThreadPool::on_worker_thread()
    });
    match pool {
        Some(p) => {
            let pieces = (p.threads() * TILES_PER_WORKER).min(cells);
            let span = cells.div_ceil(pieces);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(cells.div_ceil(span));
            for (ti, oc) in out.chunks_mut(span).enumerate() {
                jobs.push(Box::new(move || {
                    router_cells(kern, qs, es, h, dh, hkv, c, ti * span,
                                 oc);
                }));
            }
            p.scoped_run(jobs);
        }
        None => router_cells(kern, qs, es, h, dh, hkv, c, 0, &mut out),
    }
    Tensor::f32(&[b, c], out)
}

/// Pairwise LSE merge (mirrors `merge2` kernel; safe under -inf).
pub fn merge2(a: &Partials, b: &Partials) -> Partials {
    let shape_o = a.o.shape().to_vec();
    let (bb, h, dh) = (shape_o[0], shape_o[1], shape_o[2]);
    let mut o = vec![0f32; bb * h * dh];
    let mut m = vec![0f32; bb * h];
    let mut l = vec![0f32; bb * h];
    let (ao, am, al) = (a.o.as_f32(), a.m.as_f32(), a.l.as_f32());
    let (bo, bm, bl) = (b.o.as_f32(), b.m.as_f32(), b.l.as_f32());
    for i in 0..bb * h {
        let mn = am[i].max(bm[i]);
        let s1 = if am[i].is_finite() { (am[i] - mn).exp() } else { 0.0 };
        let s2 = if bm[i].is_finite() { (bm[i] - mn).exp() } else { 0.0 };
        m[i] = mn;
        l[i] = al[i] * s1 + bl[i] * s2;
        for j in 0..dh {
            o[i * dh + j] = ao[i * dh + j] * s1 + bo[i * dh + j] * s2;
        }
    }
    Partials {
        o: Tensor::f32(&[bb, h, dh], o),
        m: Tensor::f32(&[bb, h], m),
        l: Tensor::f32(&[bb, h], l),
    }
}

/// [`merge2_row_into_kern`] with the process-global kernel flavor.
pub fn merge2_row_into(dst: &mut Partials, dst_row: usize, src: &Partials,
                       src_row: usize) {
    merge2_row_into_kern(Kernels::global(), dst, dst_row, src, src_row)
}

/// In-place LSE merge of one row: `dst[dst_row] ⊕= src[src_row]`.
///
/// The scatter path of the Shared-KV batcher runs this once per (query,
/// chunk-batch) pair per layer per step — it is allocation-free by
/// design (§Perf opt 1). The per-head scale algebra is shared; the
/// o-row update runs on the flavor's [`Kernels::scale2_add`].
pub fn merge2_row_into_kern(kern: &Kernels, dst: &mut Partials,
                            dst_row: usize, src: &Partials,
                            src_row: usize) {
    let shape = dst.o.shape();
    let (h, dh) = (shape[1], shape[2]);
    let dm = dst.m.as_f32_mut();
    let dl = dst.l.as_f32_mut();
    let d0 = dst_row * h;
    let s0 = src_row * h;
    let sm = src.m.as_f32();
    let sl = src.l.as_f32();
    // first pass: scales per head. Stack scratch covers h ≤ 32; larger
    // models (e.g. 70B-class configs with 64 query heads) fall back to a
    // heap buffer instead of aborting.
    let mut stack = [0f32; 64];
    let mut heap: Vec<f32>;
    let scales: &mut [f32] = if h * 2 <= stack.len() {
        &mut stack[..h * 2]
    } else {
        heap = vec![0f32; h * 2];
        &mut heap
    };
    for i in 0..h {
        let (m1, m2) = (dm[d0 + i], sm[s0 + i]);
        let mn = m1.max(m2);
        let s1 = if m1.is_finite() { (m1 - mn).exp() } else { 0.0 };
        let s2 = if m2.is_finite() { (m2 - mn).exp() } else { 0.0 };
        dm[d0 + i] = mn;
        dl[d0 + i] = dl[d0 + i] * s1 + sl[s0 + i] * s2;
        scales[i * 2] = s1;
        scales[i * 2 + 1] = s2;
    }
    let do_ = dst.o.as_f32_mut();
    let so = src.o.as_f32();
    for i in 0..h {
        let (s1, s2) = (scales[i * 2], scales[i * 2 + 1]);
        let db = (d0 + i) * dh;
        let sb = (s0 + i) * dh;
        kern.scale2_add(&mut do_[db..db + dh], s1, &so[sb..sb + dh], s2);
    }
}

/// Normalize merged partials into the final attention output `[B,H,dh]`.
pub fn finalize(p: &Partials) -> Tensor {
    let shape = p.o.shape().to_vec();
    let (b, h, dh) = (shape[0], shape[1], shape[2]);
    let mut out = vec![0f32; b * h * dh];
    finalize_into(p, &mut out);
    Tensor::f32(&[b, h, dh], out)
}

/// [`finalize_into_kern`] with the process-global kernel flavor.
pub fn finalize_into(p: &Partials, out: &mut [f32]) {
    finalize_into_kern(Kernels::global(), p, out)
}

/// [`finalize`] into a caller-owned (arena) buffer; every element is
/// written, so the buffer needs no particular prior contents. The row
/// normalization runs on the flavor's [`Kernels::div_row`] (IEEE
/// division — identical in every flavor).
pub fn finalize_into_kern(kern: &Kernels, p: &Partials, out: &mut [f32]) {
    let shape = p.o.shape();
    let (bh, dh) = (shape[0] * shape[1], shape[2]);
    debug_assert_eq!(out.len(), bh * dh);
    let (o, l) = (p.o.as_f32(), p.l.as_f32());
    for i in 0..bh {
        let row = &mut out[i * dh..(i + 1) * dh];
        if l[i] > 0.0 {
            kern.div_row(row, &o[i * dh..(i + 1) * dh], l[i]);
        } else {
            row.fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let mut data = vec![0f32; shape.iter().product()];
        rng.fill_normal_f32(&mut data);
        Tensor::f32(shape, data)
    }

    #[test]
    fn matmul_identity() {
        let x = Tensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
        let eye = Tensor::f32(&[2, 2], vec![1., 0., 0., 1.]);
        assert_eq!(matmul(&x, &eye), x);
    }

    #[test]
    fn rms_norm_unit_output_scale() {
        let mut rng = Rng::new(0);
        let x = rand_t(&mut rng, &[3, 64]);
        let w = Tensor::f32(&[64], vec![1.0; 64]);
        let y = rms_norm(&x, &w, 1e-5);
        for i in 0..3 {
            let row = y.row(i);
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / 64.0;
            assert!((ms - 1.0).abs() < 0.01, "row {i} ms {ms}");
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(1);
        let mut x = rand_t(&mut rng, &[2, 4, 16]);
        let orig = x.clone();
        rope(&mut x, &[5, 9], 10000.0);
        for i in 0..2 {
            for h in 0..4 {
                let a = &orig.as_f32()[(i * 4 + h) * 16..(i * 4 + h + 1) * 16];
                let b = &x.as_f32()[(i * 4 + h) * 16..(i * 4 + h + 1) * 16];
                let na: f32 = a.iter().map(|v| v * v).sum();
                let nb: f32 = b.iter().map(|v| v * v).sum();
                assert!((na - nb).abs() / na.max(1e-6) < 1e-4);
            }
        }
    }

    #[test]
    fn rope_zero_pos_is_identity() {
        let mut rng = Rng::new(2);
        let mut x = rand_t(&mut rng, &[1, 2, 8]);
        let orig = x.clone();
        rope(&mut x, &[0], 10000.0);
        assert!(x.max_abs_diff(&orig) < 1e-6);
    }

    #[test]
    fn chunk_attn_identity_padding() {
        let mut rng = Rng::new(3);
        let q = rand_t(&mut rng, &[2, 4, 16]);
        let k = rand_t(&mut rng, &[64, 2, 16]);
        let v = rand_t(&mut rng, &[64, 2, 16]);
        let p = chunk_attn(&q, &k, &v, &[-1, -1], 0, 64);
        assert!(p.o.as_f32().iter().all(|&x| x == 0.0));
        assert!(p.m.as_f32().iter().all(|&x| x == f32::NEG_INFINITY));
        assert!(p.l.as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn chunk_attn_causal_visibility() {
        let mut rng = Rng::new(4);
        let q = rand_t(&mut rng, &[1, 4, 16]);
        let k = rand_t(&mut rng, &[64, 2, 16]);
        let v = rand_t(&mut rng, &[64, 2, 16]);
        // query at pos k_base+9 sees exactly 10 keys; equal to truncating
        // the chunk to valid=10 with a far-future query.
        let a = chunk_attn(&q, &k, &v, &[109], 100, 64);
        let b = chunk_attn(&q, &k, &v, &[10_000], 100, 10);
        assert!(a.o.max_abs_diff(&b.o) < 1e-5);
        assert!(a.l.max_abs_diff(&b.l) < 1e-5);
    }

    #[test]
    fn merge_identity_is_noop() {
        let mut rng = Rng::new(5);
        let q = rand_t(&mut rng, &[2, 4, 16]);
        let k = rand_t(&mut rng, &[64, 2, 16]);
        let v = rand_t(&mut rng, &[64, 2, 16]);
        let p = chunk_attn(&q, &k, &v, &[100, 200], 0, 64);
        let id = Partials::identity(2, 4, 16);
        let m1 = merge2(&p, &id);
        let m2 = merge2(&id, &p);
        assert!(m1.o.max_abs_diff(&p.o) < 1e-6);
        assert!(m2.o.max_abs_diff(&p.o) < 1e-6);
        assert!(m1.l.max_abs_diff(&p.l) < 1e-6);
    }

    #[test]
    fn chunked_equals_monolithic() {
        // the flash decomposition property, natively: two 32-token halves
        // merged == one 64-token chunk.
        let mut rng = Rng::new(6);
        let q = rand_t(&mut rng, &[4, 4, 16]);
        let k = rand_t(&mut rng, &[64, 2, 16]);
        let v = rand_t(&mut rng, &[64, 2, 16]);
        let q_pos = [63, 40, 10, 1000];
        let whole = chunk_attn(&q, &k, &v, &q_pos, 0, 64);
        let lo = chunk_attn(&q, &k.slice0(0, 32), &v.slice0(0, 32), &q_pos, 0, 32);
        let hi = chunk_attn(&q, &k.slice0(32, 64), &v.slice0(32, 64), &q_pos, 32, 32);
        let merged = merge2(&lo, &hi);
        let fa = finalize(&whole);
        let fb = finalize(&merged);
        assert!(fa.max_abs_diff(&fb) < 1e-5, "{}", fa.max_abs_diff(&fb));
    }

    #[test]
    fn merge2_row_into_many_heads_uses_heap_scratch() {
        // regression: h > 32 used to abort on the fixed [f32; 64] scratch
        let mut rng = Rng::new(40);
        let (b, h, dh) = (2, 40, 8);
        let q = rand_t(&mut rng, &[b, h, dh]);
        let k = rand_t(&mut rng, &[16, 8, dh]);
        let v = rand_t(&mut rng, &[16, 8, dh]);
        let p1 = chunk_attn(&q, &k, &v, &[100, 200], 0, 16);
        let k2 = rand_t(&mut rng, &[16, 8, dh]);
        let v2 = rand_t(&mut rng, &[16, 8, dh]);
        let p2 = chunk_attn(&q, &k2, &v2, &[100, 200], 16, 16);
        // row-wise in-place merge must equal the full merge2
        let mut acc = p1.clone();
        for row in 0..b {
            merge2_row_into(&mut acc, row, &p2, row);
        }
        let want = merge2(&p1, &p2);
        assert!(acc.o.max_abs_diff(&want.o) < 1e-6);
        assert!(acc.m.max_abs_diff(&want.m) < 1e-6);
        assert!(acc.l.max_abs_diff(&want.l) < 1e-6);
    }

    /// The determinism contract: parallel tiled kernels are bit-identical
    /// to the scalar reference across random shapes and thread counts.
    #[test]
    fn parallel_kernels_bit_identical() {
        use crate::util::threadpool::ThreadPool;
        let mut rng = Rng::new(0xBEEF);
        for &threads in &[2usize, 3, 5, 8] {
            let pool = ThreadPool::new(threads);
            for _round in 0..4 {
                // shapes chosen to cross the parallel work threshold AND
                // to leave ragged tails (non-divisible spans)
                let b = 1 + rng.below(7) as usize;
                let hkv = [1usize, 2, 4][rng.below(3) as usize];
                let group = 1 + rng.below(3) as usize;
                let h = hkv * group;
                let dh = [8usize, 16][rng.below(2) as usize];
                let c = 48 + rng.below(80) as usize;

                // matmul (deep + shallow paths)
                let d = 64 + rng.below(64) as usize;
                let n = 96 + rng.below(96) as usize;
                let x = rand_t(&mut rng, &[b, d]);
                let w = rand_t(&mut rng, &[d, n]);
                let serial = matmul(&x, &w);
                let par = matmul_exec(&x, &w, Some(&pool));
                assert_eq!(serial, par, "matmul b={b} d={d} n={n}");
                let x1 = rand_t(&mut rng, &[1, d]);
                assert_eq!(matmul(&x1, &w),
                           matmul_exec(&x1, &w, Some(&pool)),
                           "matmul col-split d={d} n={n}");

                // chunk_attn (with padding + partially visible rows)
                let q = rand_t(&mut rng, &[b, h, dh]);
                let k = rand_t(&mut rng, &[c, hkv, dh]);
                let v = rand_t(&mut rng, &[c, hkv, dh]);
                let mut q_pos: Vec<i32> = (0..b)
                    .map(|_| rng.below(2 * c as u64) as i32 - 4)
                    .collect();
                if b > 1 {
                    q_pos[0] = -1; // padding row
                }
                let serial = chunk_attn(&q, &k, &v, &q_pos, 0, c as i32);
                let par = chunk_attn_exec(&q, &k, &v, &q_pos, 0, c as i32,
                                          Some(&pool));
                assert_eq!(serial.o, par.o, "chunk_attn o b={b} h={h} c={c}");
                assert_eq!(serial.m, par.m, "chunk_attn m b={b} h={h} c={c}");
                assert_eq!(serial.l, par.l, "chunk_attn l b={b} h={h} c={c}");

                // router_score
                let embs = rand_t(&mut rng, &[c, hkv, dh]);
                assert_eq!(router_score(&q, &embs),
                           router_score_exec(&q, &embs, Some(&pool)),
                           "router b={b} h={h} c={c}");
            }
        }
    }

    /// The arena-output variant must be bit-identical to the allocating
    /// kernel, including masked (identity) rows, serial and pooled.
    #[test]
    fn chunk_attn_exec_into_bit_identical() {
        use crate::util::threadpool::ThreadPool;
        let mut rng = Rng::new(0xA7E4A);
        let pool = ThreadPool::new(3);
        for &(b, h, hkv, dh, c) in
            &[(1usize, 4usize, 2usize, 16usize, 64usize), (5, 4, 2, 16, 96)]
        {
            let q = rand_t(&mut rng, &[b, h, dh]);
            let k = rand_t(&mut rng, &[c, hkv, dh]);
            let v = rand_t(&mut rng, &[c, hkv, dh]);
            let mut q_pos: Vec<i32> =
                (0..b).map(|i| (i * 37) as i32).collect();
            if b > 1 {
                q_pos[1] = -1; // padding row stays identity
            }
            for exec_pool in [None, Some(&pool)] {
                let want = chunk_attn_exec(&q, &k, &v, &q_pos, 0, c as i32,
                                           exec_pool);
                let mut got = Partials::identity(b, h, dh);
                chunk_attn_exec_into(&q, &k, &v, &q_pos, 0, c as i32,
                                     exec_pool, &mut got);
                assert_eq!(want.o, got.o);
                assert_eq!(want.m, got.m);
                assert_eq!(want.l, got.l);
            }
        }
    }

    #[test]
    fn finalize_into_overwrites_dirty_buffer() {
        let mut rng = Rng::new(0xF1A);
        let q = rand_t(&mut rng, &[2, 4, 8]);
        let k = rand_t(&mut rng, &[16, 2, 8]);
        let v = rand_t(&mut rng, &[16, 2, 8]);
        // row 1 masked → identity partial → finalize must zero it even
        // when the output buffer arrives dirty
        let p = chunk_attn(&q, &k, &v, &[100, -1], 0, 16);
        let want = finalize(&p);
        let mut out = vec![7.0f32; 2 * 4 * 8];
        finalize_into(&p, &mut out);
        assert_eq!(out, want.as_f32());
    }

    #[test]
    fn rope_with_table_matches_rope() {
        let mut rng = Rng::new(41);
        let mut a = rand_t(&mut rng, &[2, 4, 16]);
        let mut b = a.clone();
        rope(&mut a, &[7, 123], 10000.0);
        let freqs = rope_inv_freq(16, 10000.0);
        rope_with(&mut b, &[7, 123], &freqs);
        assert_eq!(a, b);
    }

    #[test]
    fn router_scores_mean_over_heads() {
        let mut rng = Rng::new(7);
        let q = rand_t(&mut rng, &[2, 4, 16]);
        let embs = rand_t(&mut rng, &[8, 2, 16]);
        let s = router_score(&q, &embs);
        assert_eq!(s.shape(), &[2, 8]);
        // manual check of one cell
        let (b, c) = (1usize, 3usize);
        let mut want = 0f32;
        for h in 0..4 {
            let kv = h / 2;
            let qrow = &q.as_f32()[(b * 4 + h) * 16..(b * 4 + h + 1) * 16];
            let erow = &embs.as_f32()[(c * 2 + kv) * 16..(c * 2 + kv + 1) * 16];
            want += qrow.iter().zip(erow).map(|(a, b)| a * b).sum::<f32>();
        }
        want /= 4.0;
        assert!((s.as_f32()[b * 8 + c] - want).abs() < 1e-4);
    }
}
