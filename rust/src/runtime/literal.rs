//! [`Tensor`] ⇄ `xla::Literal` conversion.
//!
//! Literals are host-side XLA values; the PJRT CPU client copies them into
//! device buffers at execute time. The hot path reuses the conversion
//! helpers here; padding for batch buckets happens one level up in
//! [`backend`][super::backend].

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

/// Tensor → Literal (copies).
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        Tensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        Tensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
    };
    lit.reshape(&dims).context("literal reshape")
}

/// Literal → Tensor (copies). `shape` comes from the artifact manifest —
/// the literal's own shape is cross-checked.
pub fn from_literal(lit: &xla::Literal, shape: &[usize],
                    dtype: crate::tensor::DType) -> Result<Tensor> {
    let n: usize = shape.iter().product();
    if lit.element_count() != n {
        bail!("literal has {} elements, manifest says {:?}",
              lit.element_count(), shape);
    }
    Ok(match dtype {
        crate::tensor::DType::F32 => {
            Tensor::f32(shape, lit.to_vec::<f32>().context("literal f32")?)
        }
        crate::tensor::DType::I32 => {
            Tensor::i32(shape, lit.to_vec::<i32>().context("literal i32")?)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(&lit, &[2, 3], DType::F32).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_i32() {
        let t = Tensor::i32(&[4], vec![1, -2, 3, 4]);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(&lit, &[4], DType::I32).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn shape_mismatch_errors() {
        let t = Tensor::f32(&[4], vec![0.0; 4]);
        let lit = to_literal(&t).unwrap();
        assert!(from_literal(&lit, &[5], DType::F32).is_err());
    }
}
