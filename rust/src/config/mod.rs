//! Configuration: model architecture, serving knobs, hardware specs.
//!
//! [`ModelConfig`] is read from `artifacts/manifest.json` (the python
//! compile path is the source of truth for shapes). [`ServingConfig`] and
//! [`workload`][crate::workload] knobs are CLI/JSON-settable. Hardware
//! specs for the analytical model live in
//! [`analytical::hardware`][crate::analytical::hardware].

pub mod file;

pub use file::FileConfig;

use anyhow::Result;

use crate::util::json::Json;

/// moska-tiny architecture, mirrored from the artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub rope_theta: f64,
    pub rms_eps: f64,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            n_kv_heads: j.get("n_kv_heads")?.as_usize()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            ffn_dim: j.get("ffn_dim")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            rms_eps: j.get("rms_eps")?.as_f64()?,
        })
    }

    /// Query heads per KV head (GQA group size).
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// The moska-tiny defaults (kept in sync with python/compile/configs.py;
    /// tests cross-check against the manifest).
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            ffn_dim: 192,
            rope_theta: 10000.0,
            rms_eps: 1e-5,
        }
    }
}

/// Serving-engine knobs (paper §III.B routing + §IV workload SLO).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Top-k shared chunks per query; `None` = dense (exact) attention.
    pub top_k: Option<usize>,
    /// Max live decode batch the scheduler admits.
    pub max_batch: usize,
    /// Target per-request generation speed (paper: 35 tok/s).
    pub slo_tokens_per_sec: f64,
    /// Unique-KV pages per request cap (admission control).
    pub max_unique_pages: usize,
    /// Route once per decode step using layer-0 queries (paper's
    /// lightweight router); chunk set is reused across layers.
    pub route_every_layer: bool,
    /// Position-independent chunk composition (Universal MoSKA §III.D):
    /// shared chunks are attended with their *local* positions, allowing
    /// arbitrary chunk libraries at the cost of exactness vs a monolithic
    /// prefix (documented approximation, default off).
    pub position_independent: bool,
    /// Native-backend execution threads: `0` = auto (`MOSKA_THREADS` env
    /// or machine size), `1` = serial (bit-identical either way — see
    /// the determinism contract in `runtime::native`).
    pub exec_threads: usize,
    /// Native kernel flavor (JSON `serving.kernel`, CLI `--kernel`,
    /// `MOSKA_KERNEL` env): `auto`/`simd` = runtime-detected SIMD
    /// microkernels, `scalar` = the seed kernels (bit-exact pre-SIMD
    /// behavior), `lanes8` = the portable 8-lane flavor. See
    /// [`runtime::simd`][crate::runtime::simd].
    pub kernel: crate::runtime::simd::KernelSpec,
    /// K/V storage dtype for the shared store and paged unique cache
    /// (JSON `serving.kv_dtype`, CLI `--kv-dtype`, `MOSKA_KV_DTYPE`
    /// env): `f32` (default, bit-exact seed numerics), `f16`, `bf16`,
    /// or `int8` (per-token-row symmetric scales). Packed dtypes halve
    /// (or quarter) resident K/V bytes; the kernels widen on the fly —
    /// see the precision layer section in `runtime/README.md`.
    pub kv_dtype: crate::tensor::KvDtype,
    /// Pin execution-pool workers to cores (`sched_setaffinity`;
    /// Linux-only, no-op elsewhere). JSON `serving.pin_threads` or
    /// `MOSKA_PIN=1` — each disagg node's pool then maps onto a stable,
    /// disjoint core set (first step of the ROADMAP NUMA item).
    pub pin_threads: bool,
    /// Static domain → replica-set assignment of a domain-sharded
    /// shared store (JSON: `serving.shards` as `["legal=0", "code=1"]`;
    /// repeat a domain — `["legal=0", "legal=1"]` — to replicate it;
    /// empty = unsharded). The planner orders each step's shared-GEMM
    /// groups shard-contiguously (by primary) so per-shard batches are
    /// single slices — see
    /// [`ShardAssignment`][crate::plan::ShardAssignment] and
    /// `docs/ARCHITECTURE.md`.
    pub shards: crate::plan::ShardAssignment,
    /// Per-tick token budget shared by decode rows (1 token each) and
    /// prefill chunk tokens (JSON `serving.step_tokens`, CLI
    /// `--step-tokens`); `0` = unlimited (no budget). Only meaningful
    /// together with `prefill_chunk`.
    pub step_tokens: usize,
    /// Chunked-prefill chunk size in prompt tokens (JSON
    /// `serving.prefill_chunk`, CLI `--prefill-chunk`); `0` = whole
    /// prompt at once (the pre-chunking baseline). Keep it a multiple
    /// of the prefill slab (`max_batch.min(32)`) so chunk boundaries
    /// land on the same slab cuts as unchunked prefill — that is what
    /// makes chunked and unchunked runs bit-identical.
    pub prefill_chunk: usize,
    /// What preemption does to a displaced request's unique KV (JSON
    /// `serving.preempt_policy` as `"hold"`/`"recompute"`, CLI
    /// `--preempt`). Session-bound requests always hold.
    pub preempt_policy: crate::scheduler::PreemptPolicy,
    /// Per-tenant fair-share weights (JSON `serving.tenant_weights` as
    /// `["teamA=2", "teamB=1"]`); unlisted tenants weigh 1.0.
    pub tenant_weights: Vec<(String, f64)>,
    /// SLO-aware admission watermarks (JSON `serving.admission.*`, CLI
    /// `--admission`). Above the high watermark new `batch` work is
    /// refused (HTTP 429 + `Retry-After`), then `standard`;
    /// `interactive` is only refused at hard capacity. See
    /// [`AdmissionConfig`][crate::scheduler::AdmissionConfig] and the
    /// overload-control section of `docs/ARCHITECTURE.md`.
    pub admission: crate::scheduler::AdmissionConfig,
    /// Default end-to-end deadline per priority class, in ms (JSON
    /// `serving.deadline_ms` as `["interactive=2000", "batch=60000"]`;
    /// per-request `deadline_ms` body field overrides). A request past
    /// its deadline is cancelled between ticks — pages released,
    /// lifecycle recorded as a timeout. Unlisted classes have none.
    pub deadline_ms: Vec<(crate::scheduler::Priority, u64)>,
    /// Default time-to-first-token deadline per class, in ms (JSON
    /// `serving.ttft_deadline_ms`, body field `ttft_deadline_ms`).
    /// Expires a request that has not produced its first token in time.
    pub ttft_deadline_ms: Vec<(crate::scheduler::Priority, u64)>,
}

impl Default for ServingConfig {
    fn default() -> ServingConfig {
        ServingConfig {
            top_k: None,
            max_batch: 32,
            slo_tokens_per_sec: 35.0,
            max_unique_pages: 64,
            route_every_layer: false,
            position_independent: false,
            exec_threads: 0,
            kernel: crate::runtime::simd::KernelSpec::Auto,
            kv_dtype: crate::tensor::KvDtype::F32,
            pin_threads: false,
            shards: crate::plan::ShardAssignment::default(),
            step_tokens: 256,
            prefill_chunk: 32,
            preempt_policy: crate::scheduler::PreemptPolicy::Hold,
            tenant_weights: Vec::new(),
            admission: crate::scheduler::AdmissionConfig::default(),
            deadline_ms: Vec::new(),
            ttft_deadline_ms: Vec::new(),
        }
    }
}

impl ServingConfig {
    /// Fair-share weight of a tenant (1.0 unless configured).
    pub fn tenant_weight(&self, tenant: &str) -> f64 {
        self.tenant_weights
            .iter()
            .find(|(t, _)| t == tenant)
            .map(|&(_, w)| w)
            .unwrap_or(1.0)
    }

    /// Configured default end-to-end deadline for a priority class.
    pub fn class_deadline(&self, p: crate::scheduler::Priority)
                          -> Option<std::time::Duration> {
        self.deadline_ms
            .iter()
            .find(|&&(c, _)| c == p)
            .map(|&(_, ms)| std::time::Duration::from_millis(ms))
    }

    /// Configured default TTFT deadline for a priority class.
    pub fn class_ttft_deadline(&self, p: crate::scheduler::Priority)
                               -> Option<std::time::Duration> {
        self.ttft_deadline_ms
            .iter()
            .find(|&&(c, _)| c == p)
            .map(|&(_, ms)| std::time::Duration::from_millis(ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_weight_lookup() {
        let mut c = ServingConfig::default();
        assert_eq!(c.tenant_weight("anyone"), 1.0);
        c.tenant_weights =
            vec![("a".to_string(), 2.0), ("b".to_string(), 0.5)];
        assert_eq!(c.tenant_weight("a"), 2.0);
        assert_eq!(c.tenant_weight("b"), 0.5);
        assert_eq!(c.tenant_weight("c"), 1.0);
    }

    #[test]
    fn class_deadline_lookup() {
        use crate::scheduler::Priority;
        use std::time::Duration;
        let mut c = ServingConfig::default();
        assert_eq!(c.class_deadline(Priority::Interactive), None);
        assert_eq!(c.class_ttft_deadline(Priority::Batch), None);
        c.deadline_ms = vec![(Priority::Interactive, 2000)];
        c.ttft_deadline_ms = vec![(Priority::Interactive, 500)];
        assert_eq!(c.class_deadline(Priority::Interactive),
                   Some(Duration::from_millis(2000)));
        assert_eq!(c.class_deadline(Priority::Standard), None);
        assert_eq!(c.class_ttft_deadline(Priority::Interactive),
                   Some(Duration::from_millis(500)));
    }

    #[test]
    fn tiny_consistency() {
        let c = ModelConfig::tiny();
        assert_eq!(c.group(), 2);
        assert_eq!(c.q_dim(), 64);
        assert_eq!(c.kv_dim(), 32);
    }

    #[test]
    fn from_json() {
        let j = Json::parse(
            r#"{"vocab":256,"d_model":64,"n_layers":2,"n_heads":4,
                "n_kv_heads":2,"head_dim":16,"ffn_dim":192,
                "rope_theta":10000.0,"rms_eps":1e-5}"#,
        )
        .unwrap();
        assert_eq!(ModelConfig::from_json(&j).unwrap(), ModelConfig::tiny());
    }
}
