//! JSON config-file loading for the launcher (`--config serve.json`).
//!
//! Every CLI knob can instead live in a config file; explicit CLI flags
//! win over file values, which win over defaults — the usual layering a
//! deployable launcher needs.
//!
//! ```json
//! {
//!   "serving":  {"top_k": 16, "max_batch": 32, "slo_tokens_per_sec": 35,
//!                "route_every_layer": false, "position_independent": false,
//!                "kernel": "auto", "pin_threads": false},
//!   "backend":  "xla",
//!   "artifacts": "artifacts",
//!   "addr":     "127.0.0.1:8080",
//!   "server":   {"max_body_bytes": 1048576, "read_timeout_ms": 10000},
//!   "workload": {"rate": 8.0, "domain_skew": 1.1, "unique_only_frac": 0.1}
//! }
//! ```

use anyhow::{Context, Result};

use crate::config::ServingConfig;
use crate::util::json::Json;
use crate::workload::WorkloadConfig;

/// Parsed launcher configuration (all sections optional).
#[derive(Debug, Clone, Default)]
pub struct FileConfig {
    pub serving: Option<ServingConfig>,
    pub workload: Option<WorkloadConfig>,
    pub backend: Option<String>,
    pub artifacts: Option<String>,
    pub addr: Option<String>,
    /// HTTP acceptor body-size cap (`server.max_body_bytes`); requests
    /// declaring more get a 413 without the payload being read.
    pub http_max_body_bytes: Option<usize>,
    /// HTTP acceptor read timeout in ms (`server.read_timeout_ms`);
    /// `0` disables the timeout, stalled clients otherwise get a 408.
    pub http_read_timeout_ms: Option<u64>,
}

impl FileConfig {
    pub fn load(path: &str) -> Result<FileConfig> {
        let j = Json::read_file(path)
            .with_context(|| format!("loading config {path}"))?;
        FileConfig::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<FileConfig> {
        let mut out = FileConfig::default();
        if let Some(s) = j.opt("serving") {
            out.serving = Some(serving_from_json(s)?);
        }
        if let Some(w) = j.opt("workload") {
            out.workload = Some(workload_from_json(w)?);
        }
        if let Some(b) = j.opt("backend") {
            out.backend = Some(b.as_str()?.to_string());
        }
        if let Some(a) = j.opt("artifacts") {
            out.artifacts = Some(a.as_str()?.to_string());
        }
        if let Some(a) = j.opt("addr") {
            out.addr = Some(a.as_str()?.to_string());
        }
        if let Some(s) = j.opt("server") {
            if let Some(v) = s.opt("max_body_bytes") {
                out.http_max_body_bytes = Some(v.as_usize()?);
            }
            if let Some(v) = s.opt("read_timeout_ms") {
                out.http_read_timeout_ms = Some(v.as_usize()? as u64);
            }
        }
        Ok(out)
    }
}

fn serving_from_json(j: &Json) -> Result<ServingConfig> {
    let mut c = ServingConfig::default();
    if let Some(v) = j.opt("top_k") {
        c.top_k = match v.as_usize()? {
            0 => None,
            k => Some(k),
        };
    }
    if let Some(v) = j.opt("max_batch") {
        c.max_batch = v.as_usize()?;
    }
    if let Some(v) = j.opt("slo_tokens_per_sec") {
        c.slo_tokens_per_sec = v.as_f64()?;
    }
    if let Some(v) = j.opt("max_unique_pages") {
        c.max_unique_pages = v.as_usize()?;
    }
    if let Some(v) = j.opt("route_every_layer") {
        c.route_every_layer = v.as_bool()?;
    }
    if let Some(v) = j.opt("position_independent") {
        c.position_independent = v.as_bool()?;
    }
    if let Some(v) = j.opt("exec_threads") {
        c.exec_threads = v.as_usize()?;
    }
    if let Some(v) = j.opt("kernel") {
        c.kernel = crate::runtime::simd::KernelSpec::parse(v.as_str()?)?;
    }
    if let Some(v) = j.opt("kv_dtype") {
        let s = v.as_str()?;
        c.kv_dtype = crate::tensor::KvDtype::from_str(s)
            .with_context(|| format!(
                "unknown kv_dtype '{s}' (f32|f16|bf16|int8)"))?;
    }
    if let Some(v) = j.opt("pin_threads") {
        c.pin_threads = v.as_bool()?;
    }
    if let Some(v) = j.opt("shards") {
        let pairs: Vec<String> = v
            .as_arr()?
            .iter()
            .map(|p| Ok(p.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        c.shards = crate::plan::ShardAssignment::parse_pairs(&pairs)?;
    }
    if let Some(v) = j.opt("step_tokens") {
        c.step_tokens = v.as_usize()?;
    }
    if let Some(v) = j.opt("prefill_chunk") {
        c.prefill_chunk = v.as_usize()?;
    }
    if let Some(v) = j.opt("preempt_policy") {
        let s = v.as_str()?;
        c.preempt_policy = crate::scheduler::PreemptPolicy::from_str(s)
            .with_context(|| format!(
                "unknown preempt_policy '{s}' (hold|recompute)"))?;
    }
    if let Some(v) = j.opt("tenant_weights") {
        c.tenant_weights = v
            .as_arr()?
            .iter()
            .map(|p| {
                let s = p.as_str()?;
                let (name, w) = s.split_once('=').with_context(|| {
                    format!("tenant_weights entry '{s}' wants name=weight")
                })?;
                let w: f64 = w.parse().with_context(|| {
                    format!("bad weight in tenant_weights entry '{s}'")
                })?;
                anyhow::ensure!(w > 0.0,
                                "tenant weight must be > 0 in '{s}'");
                Ok((name.to_string(), w))
            })
            .collect::<Result<_>>()?;
    }
    if let Some(a) = j.opt("admission") {
        if let Some(v) = a.opt("enabled") {
            c.admission.enabled = v.as_bool()?;
        }
        if let Some(v) = a.opt("max_queue") {
            c.admission.max_queue = v.as_usize()?;
        }
        if let Some(v) = a.opt("max_queued_prefill_tokens") {
            c.admission.max_queued_prefill_tokens = v.as_usize()?;
        }
        if let Some(v) = a.opt("high") {
            c.admission.high = v.as_f64()?;
        }
        if let Some(v) = a.opt("low") {
            c.admission.low = v.as_f64()?;
        }
        if let Some(v) = a.opt("retry_after_ms") {
            c.admission.retry_after_secs = v.as_f64()? / 1000.0;
        }
        anyhow::ensure!(
            c.admission.low <= c.admission.high
                && c.admission.high <= 1.0
                && c.admission.low >= 0.0,
            "admission watermarks want 0 <= low <= high <= 1, got \
             low={} high={}",
            c.admission.low,
            c.admission.high,
        );
    }
    if let Some(v) = j.opt("deadline_ms") {
        c.deadline_ms = class_ms_pairs(v, "deadline_ms")?;
    }
    if let Some(v) = j.opt("ttft_deadline_ms") {
        c.ttft_deadline_ms = class_ms_pairs(v, "ttft_deadline_ms")?;
    }
    Ok(c)
}

/// Parse `["interactive=2000", "batch=60000"]`-style per-class
/// millisecond lists (the `tenant_weights` idiom).
fn class_ms_pairs(v: &Json, what: &str)
                  -> Result<Vec<(crate::scheduler::Priority, u64)>> {
    v.as_arr()?
        .iter()
        .map(|p| {
            let s = p.as_str()?;
            let (name, ms) = s.split_once('=').with_context(|| {
                format!("{what} entry '{s}' wants class=milliseconds")
            })?;
            let class = crate::scheduler::Priority::from_str(name)
                .with_context(|| format!(
                    "unknown class in {what} entry '{s}' \
                     (interactive|standard|batch)"))?;
            let ms: u64 = ms.parse().with_context(|| {
                format!("bad milliseconds in {what} entry '{s}'")
            })?;
            anyhow::ensure!(ms > 0, "{what} must be > 0 in '{s}'");
            Ok((class, ms))
        })
        .collect()
}

fn workload_from_json(j: &Json) -> Result<WorkloadConfig> {
    let mut c = WorkloadConfig::default();
    if let Some(v) = j.opt("rate") {
        c.rate = v.as_f64()?;
    }
    if let Some(v) = j.opt("domain_skew") {
        c.domain_skew = v.as_f64()?;
    }
    if let Some(v) = j.opt("unique_only_frac") {
        c.unique_only_frac = v.as_f64()?;
    }
    if let Some(v) = j.opt("domains") {
        c.domains = v
            .as_arr()?
            .iter()
            .map(|d| Ok(d.as_str()?.to_string()))
            .collect::<Result<_>>()?;
    }
    if let Some(v) = j.opt("prompt_len") {
        let r = v.as_usize_vec()?;
        anyhow::ensure!(r.len() == 2, "prompt_len wants [lo, hi]");
        c.prompt_len = (r[0], r[1]);
    }
    if let Some(v) = j.opt("max_new") {
        let r = v.as_usize_vec()?;
        anyhow::ensure!(r.len() == 2, "max_new wants [lo, hi]");
        c.max_new = (r[0], r[1]);
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config_parses() {
        let j = Json::parse(
            r#"{"serving": {"top_k": 8, "max_batch": 16,
                            "position_independent": true,
                            "exec_threads": 4},
                "backend": "native", "addr": "0.0.0.0:9090",
                "workload": {"rate": 3.5, "domains": ["legal"],
                             "prompt_len": [4, 9]}}"#,
        )
        .unwrap();
        let c = FileConfig::from_json(&j).unwrap();
        let s = c.serving.unwrap();
        assert_eq!(s.top_k, Some(8));
        assert_eq!(s.max_batch, 16);
        assert!(s.position_independent);
        assert_eq!(s.exec_threads, 4);
        assert_eq!(c.backend.as_deref(), Some("native"));
        let w = c.workload.unwrap();
        assert_eq!(w.rate, 3.5);
        assert_eq!(w.domains, vec!["legal"]);
        assert_eq!(w.prompt_len, (4, 9));
    }

    #[test]
    fn empty_config_is_default() {
        let c = FileConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(c.serving.is_none());
        assert!(c.backend.is_none());
        assert!(c.http_max_body_bytes.is_none());
        assert!(c.http_read_timeout_ms.is_none());
    }

    #[test]
    fn server_limits_parse() {
        let j = Json::parse(
            r#"{"server": {"max_body_bytes": 65536, "read_timeout_ms": 0}}"#,
        )
        .unwrap();
        let c = FileConfig::from_json(&j).unwrap();
        assert_eq!(c.http_max_body_bytes, Some(65536));
        assert_eq!(c.http_read_timeout_ms, Some(0));
    }

    #[test]
    fn shards_assignment_parses() {
        let j = Json::parse(
            r#"{"serving": {"shards": ["legal=0", "code=1"]}}"#,
        )
        .unwrap();
        let s = FileConfig::from_json(&j).unwrap().serving.unwrap();
        assert_eq!(s.shards.shard_of("legal"), Some(0));
        assert_eq!(s.shards.shard_of("code"), Some(1));
        assert_eq!(s.shards.n_shards, 2);
        let bad =
            Json::parse(r#"{"serving": {"shards": ["legal"]}}"#).unwrap();
        assert!(FileConfig::from_json(&bad).is_err());
    }

    #[test]
    fn kernel_and_pinning_parse() {
        let j = Json::parse(
            r#"{"serving": {"kernel": "scalar", "pin_threads": true}}"#,
        )
        .unwrap();
        let s = FileConfig::from_json(&j).unwrap().serving.unwrap();
        assert_eq!(s.kernel, crate::runtime::simd::KernelSpec::Scalar);
        assert!(s.pin_threads);
        let bad =
            Json::parse(r#"{"serving": {"kernel": "sse9"}}"#).unwrap();
        assert!(FileConfig::from_json(&bad).is_err());
    }

    #[test]
    fn kv_dtype_parses() {
        use crate::tensor::KvDtype;
        let s = FileConfig::from_json(
            &Json::parse(r#"{"serving": {"kv_dtype": "f16"}}"#).unwrap(),
        )
        .unwrap()
        .serving
        .unwrap();
        assert_eq!(s.kv_dtype, KvDtype::F16);
        assert_eq!(ServingConfig::default().kv_dtype, KvDtype::F32);
        let bad = Json::parse(r#"{"serving": {"kv_dtype": "fp4"}}"#)
            .unwrap();
        assert!(FileConfig::from_json(&bad).is_err());
    }

    #[test]
    fn serving_loop_knobs_parse() {
        use crate::scheduler::PreemptPolicy;
        let j = Json::parse(
            r#"{"serving": {"step_tokens": 128, "prefill_chunk": 64,
                            "preempt_policy": "recompute",
                            "tenant_weights": ["teamA=2", "teamB=0.5"]}}"#,
        )
        .unwrap();
        let s = FileConfig::from_json(&j).unwrap().serving.unwrap();
        assert_eq!(s.step_tokens, 128);
        assert_eq!(s.prefill_chunk, 64);
        assert_eq!(s.preempt_policy, PreemptPolicy::Recompute);
        assert_eq!(s.tenant_weight("teamA"), 2.0);
        assert_eq!(s.tenant_weight("teamB"), 0.5);
        assert_eq!(s.tenant_weight("other"), 1.0);
        let d = ServingConfig::default();
        assert_eq!(d.step_tokens, 256);
        assert_eq!(d.prefill_chunk, 32);
        assert_eq!(d.preempt_policy, PreemptPolicy::Hold);
        for bad in [
            r#"{"serving": {"preempt_policy": "drop"}}"#,
            r#"{"serving": {"tenant_weights": ["teamA"]}}"#,
            r#"{"serving": {"tenant_weights": ["teamA=fast"]}}"#,
            r#"{"serving": {"tenant_weights": ["teamA=0"]}}"#,
        ] {
            assert!(FileConfig::from_json(&Json::parse(bad).unwrap())
                        .is_err(),
                    "{bad} should be rejected");
        }
    }

    #[test]
    fn admission_and_deadlines_parse() {
        use crate::scheduler::Priority;
        let j = Json::parse(
            r#"{"serving": {
                  "admission": {"enabled": true, "max_queue": 64,
                                "max_queued_prefill_tokens": 4096,
                                "high": 0.7, "low": 0.3,
                                "retry_after_ms": 250},
                  "deadline_ms": ["interactive=2000", "batch=60000"],
                  "ttft_deadline_ms": ["interactive=500"]}}"#,
        )
        .unwrap();
        let s = FileConfig::from_json(&j).unwrap().serving.unwrap();
        assert!(s.admission.enabled);
        assert_eq!(s.admission.max_queue, 64);
        assert_eq!(s.admission.max_queued_prefill_tokens, 4096);
        assert_eq!(s.admission.high, 0.7);
        assert_eq!(s.admission.low, 0.3);
        assert!((s.admission.retry_after_secs - 0.25).abs() < 1e-12);
        assert_eq!(s.class_deadline(Priority::Interactive),
                   Some(std::time::Duration::from_millis(2000)));
        assert_eq!(s.class_deadline(Priority::Batch),
                   Some(std::time::Duration::from_millis(60000)));
        assert_eq!(s.class_deadline(Priority::Standard), None);
        assert_eq!(s.class_ttft_deadline(Priority::Interactive),
                   Some(std::time::Duration::from_millis(500)));
        // defaults: watermarks on, no deadlines
        let d = ServingConfig::default();
        assert!(d.admission.enabled);
        assert!(d.deadline_ms.is_empty());
        for bad in [
            r#"{"serving": {"admission": {"high": 0.3, "low": 0.6}}}"#,
            r#"{"serving": {"admission": {"high": 1.5}}}"#,
            r#"{"serving": {"deadline_ms": ["vip=100"]}}"#,
            r#"{"serving": {"deadline_ms": ["interactive"]}}"#,
            r#"{"serving": {"deadline_ms": ["interactive=soon"]}}"#,
            r#"{"serving": {"ttft_deadline_ms": ["batch=0"]}}"#,
        ] {
            assert!(FileConfig::from_json(&Json::parse(bad).unwrap())
                        .is_err(),
                    "{bad} should be rejected");
        }
    }

    #[test]
    fn top_k_zero_means_dense() {
        let j = Json::parse(r#"{"serving": {"top_k": 0}}"#).unwrap();
        let c = FileConfig::from_json(&j).unwrap();
        assert_eq!(c.serving.unwrap().top_k, None);
    }

    #[test]
    fn bad_shapes_error() {
        let j = Json::parse(r#"{"serving": {"max_batch": "lots"}}"#).unwrap();
        assert!(FileConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"workload": {"prompt_len": [1]}}"#).unwrap();
        assert!(FileConfig::from_json(&j).is_err());
    }
}
