//! `moska loadgen` — deterministic traffic generator for the serving
//! loop.
//!
//! Scenario mixes model the paper's serving workloads over the
//! synthetic shared store: RAG fleets over shared corpora
//! (`rag-shared`), multi-turn chat with shared prompt prefixes
//! (`chat-prefix`), agent swarms hammering one domain (`agent-swarm`),
//! a long-prompt/short-prompt interleaving stress (`long-short`), and
//! a round-robin of all four (`mixed`). Item streams are pure
//! functions of (scenario, n, seed) — identical across runs and
//! platforms — so traces can be recorded, diffed, and replayed.
//!
//! Drive modes share the same items:
//! * **in-process closed-loop** (`--addr ''`): every item submitted up
//!   front against a fresh
//!   [`synthetic_engine`][crate::disagg::synthetic_engine]; TTFT/TPOT
//!   come from engine lifecycle timings, token/mix counts are
//!   seed-deterministic. The `chat-prefix` scenario routes through the
//!   sessions API (real per-conversation KV reuse).
//! * **HTTP closed-loop** (`--addr host:port`): worker threads POST
//!   `/generate` with `"stream": true` and time the SSE frames off the
//!   wire — TTFT is the first `data:` frame, TPOT the inter-frame
//!   mean.
//! * **open-loop** (`--open-loop`, both in-process and HTTP): arrival
//!   timestamps are *honored*, not waited on — a refused or expired
//!   request is a shed/timeout measurement, never a retry. This is the
//!   one arrival-pacing implementation in the tree
//!   ([`drive_open_loop`]); `moska replay` is a thin alias over it.
//!   `--sweep` adds the overload sweep (offered rate × capacity, with
//!   admission on, plus a no-admission collapse baseline) to the
//!   report as `open_loop_sweep`.
//!
//! Reports land in `bench_out/BENCH_serving.json` (keys merged over an
//! existing report so independent smokes compose); `scripts/ci.sh`
//! gates on zero errors, nonzero streamed tokens, and finite latency
//! quantiles. `--compare-chunking` adds the chunked-vs-unchunked
//! short-request TTFT probe measured in deterministic work units.

use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::ServingConfig;
use crate::disagg::{SYNTH_DOMAIN, SYNTH_DOMAIN_B};
use crate::engine::{AdmitError, Engine, SubmitOpts};
use crate::model::sampling::Sampler;
use crate::scheduler::{AdmissionConfig, Priority};
use crate::util::bench::Stats;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::WorkItem;

/// Shared conversation-prefix length (tokens) in the `chat-prefix`
/// scenario; the sessions driver resends only the post-prefix suffix
/// on later turns of a conversation.
pub const CHAT_PREFIX_TOKENS: usize = 12;

/// Named traffic mix (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    RagShared,
    ChatPrefix,
    AgentSwarm,
    LongShort,
    Mixed,
}

impl Scenario {
    pub fn from_str(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "rag-shared" => Some(Scenario::RagShared),
            "chat-prefix" => Some(Scenario::ChatPrefix),
            "agent-swarm" => Some(Scenario::AgentSwarm),
            "long-short" => Some(Scenario::LongShort),
            "mixed" => Some(Scenario::Mixed),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Scenario::RagShared => "rag-shared",
            Scenario::ChatPrefix => "chat-prefix",
            Scenario::AgentSwarm => "agent-swarm",
            Scenario::LongShort => "long-short",
            Scenario::Mixed => "mixed",
        }
    }

    pub fn all() -> [Scenario; 5] {
        [Scenario::RagShared, Scenario::ChatPrefix, Scenario::AgentSwarm,
         Scenario::LongShort, Scenario::Mixed]
    }
}

/// One prompt token: lowercase ASCII so the byte-level tokenizer
/// round-trips it through the HTTP JSON body unchanged.
fn tok(rng: &mut Rng) -> i32 {
    97 + rng.below(26) as i32
}

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| tok(rng)).collect()
}

/// Deterministic item stream: a pure function of (scenario, n, seed).
pub fn scenario_items(s: Scenario, n: usize, seed: u64) -> Vec<WorkItem> {
    let mut rng = Rng::new(seed);
    // chat conversations share fixed per-seed prefixes (drawn up front
    // so every turn of a conversation reuses the same bytes)
    let prefixes: Vec<Vec<i32>> =
        (0..4).map(|_| prompt(&mut rng, 12)).collect();
    let mut clock = 0.0;
    (0..n)
        .map(|i| {
            let kind = match s {
                Scenario::Mixed => {
                    [Scenario::RagShared, Scenario::ChatPrefix,
                     Scenario::AgentSwarm, Scenario::LongShort][i % 4]
                }
                k => k,
            };
            let rate = match kind {
                Scenario::AgentSwarm => 100.0,
                _ => 20.0,
            };
            clock += rng.exponential(rate);
            let mut w = match kind {
                Scenario::RagShared => {
                    // two RAG tenants over the two shared corpora
                    let domain = if i % 4 == 3 {
                        SYNTH_DOMAIN_B
                    } else {
                        SYNTH_DOMAIN
                    };
                    let plen = rng.range(8, 25);
                    let p = prompt(&mut rng, plen);
                    let mut w = WorkItem::basic(
                        clock, Some(domain.into()), p, rng.range(4, 9),
                    );
                    w.tenant = if i % 2 == 0 { "rag-a" } else { "rag-b" }
                        .to_string();
                    w
                }
                Scenario::ChatPrefix => {
                    // turn = shared conversation prefix + fresh suffix
                    let conv = rng.range(0, prefixes.len());
                    let mut p = prefixes[conv].clone();
                    let extra = rng.range(4, 9);
                    p.extend((0..extra).map(|_| tok(&mut rng)));
                    let mut w = WorkItem::basic(
                        clock, None, p, rng.range(4, 11),
                    );
                    w.tenant = format!("chat-{conv}");
                    w.priority = Priority::Interactive;
                    w
                }
                Scenario::AgentSwarm => {
                    // one tenant, one corpus, short bursty requests
                    let p = prompt(&mut rng, rng.range(4, 9));
                    let mut w = WorkItem::basic(
                        clock, Some(SYNTH_DOMAIN.into()), p,
                        rng.range(2, 5),
                    );
                    w.tenant = "swarm".to_string();
                    w.priority = Priority::Batch;
                    w
                }
                Scenario::LongShort => {
                    // a long batch prompt every 8th item, interactive
                    // shorts in between — the chunked-prefill stress
                    if i % 8 == 0 {
                        let p = prompt(&mut rng, rng.range(96, 129));
                        let mut w = WorkItem::basic(
                            clock, Some(SYNTH_DOMAIN.into()), p, 4,
                        );
                        w.tenant = "batch".to_string();
                        w.priority = Priority::Batch;
                        w
                    } else {
                        let p = prompt(&mut rng, rng.range(4, 9));
                        let mut w = WorkItem::basic(
                            clock, Some(SYNTH_DOMAIN.into()), p, 4,
                        );
                        w.tenant = "chat".to_string();
                        w.priority = Priority::Interactive;
                        w
                    }
                }
                Scenario::Mixed => unreachable!(),
            };
            w.stream = true;
            w
        })
        .collect()
}

/// One request's client-side timings.
struct ReqSample {
    ttft_secs: f64,
    tpot_secs: Option<f64>,
    tokens: usize,
}

/// Aggregated loadgen run, serialized to `BENCH_serving.json`.
pub struct Report {
    pub scenario: &'static str,
    pub mode: &'static str,
    pub seed: u64,
    pub requests: usize,
    pub errors: usize,
    pub streamed_tokens: usize,
    pub generated_tokens: usize,
    pub elapsed_secs: f64,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    mix_domains: BTreeMap<String, usize>,
    mix_tenants: BTreeMap<String, usize>,
    pub chunking: Option<Json>,
    pub first_error: Option<String>,
    /// Session-reuse accounting (`chat-prefix` in-process runs).
    pub sessions: Option<Json>,
    /// Open-loop columns (shed/timeout counts, per-class percentiles).
    pub open_loop: Option<Json>,
}

fn quantiles(samples: &[f64]) -> (f64, f64) {
    if samples.is_empty() {
        return (0.0, 0.0);
    }
    let s = Stats::from_samples(
        samples.iter().map(|&v| Duration::from_secs_f64(v)).collect(),
    );
    (s.p50.as_secs_f64(), s.p99.as_secs_f64())
}

/// The seed-deterministic request mix of an item stream (what the
/// determinism tests diff across runs).
fn mix_of(items: &[WorkItem])
          -> (BTreeMap<String, usize>, BTreeMap<String, usize>) {
    let mut domains = BTreeMap::new();
    let mut tenants = BTreeMap::new();
    for w in items {
        let d = w.domain.clone().unwrap_or_else(|| "unique".to_string());
        *domains.entry(d).or_insert(0) += 1;
        *tenants.entry(w.tenant.clone()).or_insert(0) += 1;
    }
    (domains, tenants)
}

impl Report {
    pub fn to_json(&self) -> Json {
        let (ttft_p50, ttft_p99) = quantiles(&self.ttft);
        let (tpot_p50, tpot_p99) = quantiles(&self.tpot);
        let count_map = |m: &BTreeMap<String, usize>| {
            Json::obj(
                m.iter()
                    .map(|(k, v)| (k.as_str(), Json::num(*v as f64)))
                    .collect(),
            )
        };
        let goodput = if self.elapsed_secs > 0.0 {
            (self.requests - self.errors) as f64 / self.elapsed_secs
        } else {
            0.0
        };
        let mut fields = vec![
            ("scenario", Json::str(self.scenario)),
            ("mode", Json::str(self.mode)),
            ("seed", Json::num(self.seed as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("streamed_tokens", Json::num(self.streamed_tokens as f64)),
            ("generated_tokens", Json::num(self.generated_tokens as f64)),
            ("elapsed_secs", Json::num(self.elapsed_secs)),
            ("ttft_p50_ms", Json::num(ttft_p50 * 1e3)),
            ("ttft_p99_ms", Json::num(ttft_p99 * 1e3)),
            ("tpot_p50_ms", Json::num(tpot_p50 * 1e3)),
            ("tpot_p99_ms", Json::num(tpot_p99 * 1e3)),
            ("goodput_rps", Json::num(goodput)),
            ("mix", Json::obj(vec![
                ("domains", count_map(&self.mix_domains)),
                ("tenants", count_map(&self.mix_tenants)),
            ])),
        ];
        if let Some(c) = &self.chunking {
            fields.push(("chunking_compare", c.clone()));
        }
        if let Some(s) = &self.sessions {
            fields.push(("sessions", s.clone()));
        }
        if let Some(o) = &self.open_loop {
            fields.push(("open_loop", o.clone()));
        }
        if let Some(e) = &self.first_error {
            fields.push(("first_error", Json::str(e.clone())));
        }
        Json::obj(fields)
    }
}

/// Closed-loop in-process run: submit every item against a fresh
/// synthetic engine, drain to completion, report lifecycle timings.
/// Token and mix columns are pure functions of (scenario, seed, n).
/// `chat-prefix` routes through the sessions API so conversation
/// prefixes are *actually* reused from session KV, not re-prefilled.
pub fn run_inprocess(scenario: Scenario, items: &[WorkItem], seed: u64)
                     -> Result<Report> {
    if scenario == Scenario::ChatPrefix {
        return run_inprocess_sessions(items, seed);
    }
    let mut eng =
        crate::disagg::synthetic_engine(ServingConfig::default())?;
    let t0 = Instant::now();
    for w in items {
        eng.submit_opts(w.domain.as_deref(), w.prompt.clone(), w.max_new,
                        Sampler::Greedy, &w.tenant, w.priority)?;
    }
    let results = eng.run_to_completion()?;
    let elapsed = t0.elapsed().as_secs_f64();
    let streamed = eng.take_emitted().len();
    let mut ttft = Vec::new();
    let mut tpot = Vec::new();
    let mut generated = 0usize;
    for r in &results {
        ttft.push(r.queue_secs + r.prefill_secs);
        if r.tokens.len() > 1 {
            tpot.push(r.decode_secs / (r.tokens.len() - 1) as f64);
        }
        generated += r.tokens.len();
    }
    let (mix_domains, mix_tenants) = mix_of(items);
    Ok(Report {
        scenario: scenario.as_str(),
        mode: "inprocess",
        seed,
        requests: results.len(),
        errors: items.len() - results.len(),
        streamed_tokens: streamed,
        generated_tokens: generated,
        elapsed_secs: elapsed,
        ttft,
        tpot,
        mix_domains,
        mix_tenants,
        chunking: None,
        first_error: None,
        sessions: None,
        open_loop: None,
    })
}

/// The sessions-routed `chat-prefix` driver: one engine session per
/// conversation tenant; turns run in item order, and every turn after
/// the first resends only the fresh suffix — the shared prefix (and
/// all prior turns) comes from the parked session KV.
fn run_inprocess_sessions(items: &[WorkItem], seed: u64)
                          -> Result<Report> {
    let mut eng =
        crate::disagg::synthetic_engine(ServingConfig::default())?;
    // group item indices by conversation, preserving turn order
    let mut convs: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (i, w) in items.iter().enumerate() {
        convs.entry(w.tenant.clone()).or_default().push(i);
    }
    let t0 = Instant::now();
    let mut ttft = Vec::new();
    let mut tpot = Vec::new();
    let mut generated = 0usize;
    let mut streamed = 0usize;
    let mut completed = 0usize;
    let mut turns = 0usize;
    let mut reuse_hits = 0usize;
    let mut reused_context_tokens = 0usize;
    for idxs in convs.values() {
        let sid = eng.open_session(None)?;
        for (k, &i) in idxs.iter().enumerate() {
            let w = &items[i];
            let prompt = if k == 0
                || w.prompt.len() <= CHAT_PREFIX_TOKENS
            {
                w.prompt.clone()
            } else {
                // prefix KV already lives in the session
                let ctx = eng
                    .session(sid)
                    .map(|s| s.context_tokens())
                    .unwrap_or(0);
                if ctx > 0 {
                    reuse_hits += 1;
                    reused_context_tokens += ctx;
                }
                w.prompt[CHAT_PREFIX_TOKENS..].to_vec()
            };
            eng.submit_turn(sid, prompt, w.max_new, Sampler::Greedy)?;
            // a session allows one turn in flight: drain before the next
            for r in eng.run_to_completion()? {
                ttft.push(r.queue_secs + r.prefill_secs);
                if r.tokens.len() > 1 {
                    tpot.push(
                        r.decode_secs / (r.tokens.len() - 1) as f64);
                }
                generated += r.tokens.len();
                completed += 1;
            }
            streamed += eng.take_emitted().len();
            turns += 1;
        }
        eng.close_session(sid)?;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let (mix_domains, mix_tenants) = mix_of(items);
    Ok(Report {
        scenario: Scenario::ChatPrefix.as_str(),
        mode: "inprocess",
        seed,
        requests: completed,
        errors: items.len() - completed,
        streamed_tokens: streamed,
        generated_tokens: generated,
        elapsed_secs: elapsed,
        ttft,
        tpot,
        mix_domains,
        mix_tenants,
        chunking: None,
        first_error: None,
        sessions: Some(Json::obj(vec![
            ("conversations", Json::num(convs.len() as f64)),
            ("turns", Json::num(turns as f64)),
            ("reuse_hits", Json::num(reuse_hits as f64)),
            ("reused_context_tokens",
             Json::num(reused_context_tokens as f64)),
        ])),
        open_loop: None,
    })
}

/// Closed-loop HTTP run: `concurrency` workers each stream one request
/// at a time over raw sockets until the deadline (or every item once
/// when `seconds == 0`).
pub fn run_http(addr: &str, scenario: Scenario, items: &[WorkItem],
                seed: u64, concurrency: usize, seconds: f64)
                -> Result<Report> {
    if items.is_empty() {
        bail!("no work items");
    }
    let next = AtomicUsize::new(0);
    let deadline = (seconds > 0.0)
        .then(|| Instant::now() + Duration::from_secs_f64(seconds));
    let out: Mutex<Vec<Result<ReqSample>>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    std::thread::scope(|sc| {
        for _ in 0..concurrency.max(1) {
            sc.spawn(|| {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let stop = match deadline {
                        Some(d) => Instant::now() >= d,
                        None => i >= items.len(),
                    };
                    if stop {
                        break;
                    }
                    local.push(sse_request(addr, &items[i % items.len()]));
                }
                out.lock().unwrap().extend(local);
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let samples = out.into_inner().unwrap();
    let mut ttft = Vec::new();
    let mut tpot = Vec::new();
    let mut streamed = 0usize;
    let mut errors = 0usize;
    let mut first_error = None;
    let requests = samples.len();
    for s in samples {
        match s {
            Ok(s) => {
                ttft.push(s.ttft_secs);
                if let Some(t) = s.tpot_secs {
                    tpot.push(t);
                }
                streamed += s.tokens;
            }
            Err(e) => {
                errors += 1;
                first_error.get_or_insert_with(|| format!("{e:#}"));
            }
        }
    }
    let (mix_domains, mix_tenants) = mix_of(items);
    Ok(Report {
        scenario: scenario.as_str(),
        mode: "http",
        seed,
        requests,
        errors,
        streamed_tokens: streamed,
        generated_tokens: streamed,
        elapsed_secs: elapsed,
        ttft,
        tpot,
        mix_domains,
        mix_tenants,
        chunking: None,
        first_error,
        sessions: None,
        open_loop: None,
    })
}

// ------------------------------------------------------ open-loop drive

/// Per-priority-class aggregate of one open-loop run.
#[derive(Debug, Clone, Default)]
pub struct ClassAgg {
    pub offered: usize,
    pub completed: usize,
    /// Refused by admission (watermark shed or hard cap).
    pub shed: usize,
    /// Cancelled by deadline expiry.
    pub timeout: usize,
    pub errors: usize,
    pub tokens: usize,
    pub ttft: Vec<f64>,
    pub queue: Vec<f64>,
}

/// One open-loop drive: what was offered vs what survived.
#[derive(Debug, Default)]
pub struct OpenLoopRun {
    pub offered: usize,
    pub completed: usize,
    pub streamed_tokens: usize,
    pub generated_tokens: usize,
    pub elapsed_secs: f64,
    pub per_class: BTreeMap<&'static str, ClassAgg>,
    pub queue_secs: Vec<f64>,
    /// Completion-order TTFTs (order matters: the collapse baseline's
    /// trend statistic compares the run's halves).
    pub ttft_secs: Vec<f64>,
    pub per_token_secs: Vec<f64>,
}

impl OpenLoopRun {
    fn class(&mut self, cls: &'static str) -> &mut ClassAgg {
        self.per_class.entry(cls).or_default()
    }

    pub fn shed(&self) -> usize {
        self.per_class.values().map(|c| c.shed).sum()
    }

    pub fn timeouts(&self) -> usize {
        self.per_class.values().map(|c| c.timeout).sum()
    }

    pub fn errors(&self) -> usize {
        self.per_class.values().map(|c| c.errors).sum()
    }
}

/// THE arrival-pacing implementation (in-process): submit each item
/// when its (scale-compressed) arrival timestamp comes due, step the
/// engine continuously, and *measure* what the engine refuses —
/// admission rejections count as sheds and deadline expiries as
/// timeouts; arrivals are never dropped or retried. `moska replay`
/// and the loadgen open-loop/sweep modes all drive through here.
pub fn drive_open_loop(engine: &mut Engine, items: &[WorkItem],
                       scale: f64) -> Result<OpenLoopRun> {
    let scale = if scale > 0.0 { scale } else { 1.0 };
    let mut run = OpenLoopRun { offered: items.len(), ..Default::default() };
    let mut class_of: HashMap<usize, &'static str> = HashMap::new();
    let t0 = Instant::now();
    let mut next = 0usize;
    loop {
        let now = t0.elapsed().as_secs_f64();
        while next < items.len() && items[next].arrival / scale <= now {
            let it = &items[next];
            next += 1;
            let cls = it.priority.as_str();
            run.class(cls).offered += 1;
            let opts = SubmitOpts {
                tenant: it.tenant.clone(),
                priority: it.priority,
                deadline: it.deadline_ms.map(Duration::from_millis),
                ttft_deadline: None,
            };
            match engine.submit_with(it.domain.as_deref(),
                                     it.prompt.clone(), it.max_new,
                                     Sampler::Greedy, opts) {
                Ok(id) => {
                    class_of.insert(id, cls);
                }
                Err(e) if e.downcast_ref::<AdmitError>().is_some() => {
                    run.class(cls).shed += 1;
                }
                Err(_) => run.class(cls).errors += 1,
            }
        }
        if engine.has_work() {
            engine.step()?;
        } else if next < items.len() {
            // idle until the next arrival
            let wait =
                items[next].arrival / scale - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(
                    wait.min(0.010),
                ));
            }
        }
        run.streamed_tokens += engine.take_emitted().len();
        for (id, _why) in engine.take_expired() {
            if let Some(cls) = class_of.remove(&id) {
                run.class(cls).timeout += 1;
            }
        }
        for r in engine.take_results() {
            let cls = class_of.remove(&r.id).unwrap_or("standard");
            run.completed += 1;
            run.generated_tokens += r.tokens.len();
            let ttft = r.queue_secs + r.prefill_secs;
            run.queue_secs.push(r.queue_secs);
            run.ttft_secs.push(ttft);
            if !r.tokens.is_empty() {
                run.per_token_secs
                    .push(r.decode_secs / r.tokens.len() as f64);
            }
            let c = run.class(cls);
            c.completed += 1;
            c.tokens += r.tokens.len();
            c.ttft.push(ttft);
            c.queue.push(r.queue_secs);
        }
        if next >= items.len() && !engine.has_work() {
            break;
        }
    }
    run.elapsed_secs = t0.elapsed().as_secs_f64();
    Ok(run)
}

/// Deterministically re-time a trace as a single Poisson arrival
/// process at `rate` req/s — the sweep's controlled variable.
pub fn retime_poisson(items: &[WorkItem], rate: f64, seed: u64)
                      -> Vec<WorkItem> {
    let mut rng =
        Rng::new(seed.wrapping_mul(0x9E37_79B9).wrapping_add(0xA5));
    let mut clock = 0.0;
    items
        .iter()
        .map(|w| {
            let mut w = w.clone();
            clock += rng.exponential(rate);
            w.arrival = clock;
            w
        })
        .collect()
}

fn class_agg_json(c: &ClassAgg) -> Json {
    let (tp50, tp99) = quantiles(&c.ttft);
    let (qp50, qp99) = quantiles(&c.queue);
    Json::obj(vec![
        ("offered", Json::num(c.offered as f64)),
        ("completed", Json::num(c.completed as f64)),
        ("shed", Json::num(c.shed as f64)),
        ("timeout", Json::num(c.timeout as f64)),
        ("errors", Json::num(c.errors as f64)),
        ("tokens", Json::num(c.tokens as f64)),
        ("ttft_p50_ms", Json::num(tp50 * 1e3)),
        ("ttft_p99_ms", Json::num(tp99 * 1e3)),
        ("queue_p50_ms", Json::num(qp50 * 1e3)),
        ("queue_p99_ms", Json::num(qp99 * 1e3)),
    ])
}

/// The open-loop report columns shared by report and sweep points.
fn open_loop_fields(run: &OpenLoopRun) -> Vec<(&'static str, Json)> {
    let goodput = if run.elapsed_secs > 0.0 {
        run.completed as f64 / run.elapsed_secs
    } else {
        0.0
    };
    let (tp50, tp99) = quantiles(&run.ttft_secs);
    let (qp50, qp99) = quantiles(&run.queue_secs);
    vec![
        ("offered", Json::num(run.offered as f64)),
        ("completed", Json::num(run.completed as f64)),
        ("shed", Json::num(run.shed() as f64)),
        ("timeouts", Json::num(run.timeouts() as f64)),
        ("errors", Json::num(run.errors() as f64)),
        ("elapsed_secs", Json::num(run.elapsed_secs)),
        ("goodput_rps", Json::num(goodput)),
        ("ttft_p50_ms", Json::num(tp50 * 1e3)),
        ("ttft_p99_ms", Json::num(tp99 * 1e3)),
        ("queue_p50_ms", Json::num(qp50 * 1e3)),
        ("queue_p99_ms", Json::num(qp99 * 1e3)),
        ("per_class", Json::obj(
            run.per_class
                .iter()
                .map(|(k, c)| (*k, class_agg_json(c)))
                .collect(),
        )),
    ]
}

/// Second-half / first-half mean TTFT in completion order: ≈ 1 for a
/// stable queue, growing past 1 when the queue diverges (the
/// queueing-collapse signature).
fn ttft_trend(ttft: &[f64]) -> f64 {
    if ttft.len() < 4 {
        return 1.0;
    }
    let mid = ttft.len() / 2;
    let mean = |s: &[f64]| s.iter().sum::<f64>() / s.len() as f64;
    mean(&ttft[mid..]) / mean(&ttft[..mid]).max(1e-9)
}

/// Sweep serving config. With admission on, the watermarks are tuned
/// so batch sheds early under overload while interactive never hits
/// the hard queue cap at this scale; standard work additionally gets a
/// deadline so the timeout path is exercised. With admission off, the
/// hard caps are pushed out of reach — the queue grows without bound.
fn sweep_config(admission_on: bool) -> ServingConfig {
    let mut cfg = ServingConfig::default();
    cfg.admission = if admission_on {
        AdmissionConfig {
            enabled: true,
            max_queue: 128,
            max_queued_prefill_tokens: 4096,
            high: 0.10,
            low: 0.05,
            retry_after_secs: 0.25,
        }
    } else {
        AdmissionConfig {
            enabled: false,
            max_queue: 1_000_000,
            max_queued_prefill_tokens: 1_000_000_000,
            ..Default::default()
        }
    };
    if admission_on {
        cfg.deadline_ms = vec![(Priority::Standard, 2000)];
    }
    cfg
}

/// The open-loop overload sweep behind `--sweep`: calibrate peak
/// service rate closed-loop (admission off), then offer Poisson
/// arrivals at 0.5×/1×/2× capacity with admission on — goodput should
/// hold near capacity through the 2× point while batch sheds absorb
/// the overload — plus a no-admission baseline at 2× whose
/// `ttft_trend` > 1 shows the queue diverging.
pub fn overload_sweep(n: usize, seed: u64) -> Result<Json> {
    let items = scenario_items(Scenario::Mixed, n, seed);
    // closed-loop calibration: peak completions/sec
    let mut eng = crate::disagg::synthetic_engine(sweep_config(false))?;
    let t0 = Instant::now();
    for w in &items {
        eng.submit_opts(w.domain.as_deref(), w.prompt.clone(), w.max_new,
                        Sampler::Greedy, &w.tenant, w.priority)?;
    }
    let done = eng.run_to_completion()?.len();
    let capacity_rps = done as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    let mut points = Vec::new();
    for scale in [0.5, 1.0, 2.0] {
        let rate = capacity_rps * scale;
        let timed = retime_poisson(&items, rate, seed);
        let mut eng =
            crate::disagg::synthetic_engine(sweep_config(true))?;
        let run = drive_open_loop(&mut eng, &timed, 1.0)?;
        let mut point = vec![
            ("rate_scale", Json::num(scale)),
            ("offered_rps", Json::num(rate)),
        ];
        point.extend(open_loop_fields(&run));
        points.push(Json::obj(point));
    }
    let rate = capacity_rps * 2.0;
    let timed = retime_poisson(&items, rate, seed);
    let mut eng = crate::disagg::synthetic_engine(sweep_config(false))?;
    let run = drive_open_loop(&mut eng, &timed, 1.0)?;
    let mut baseline = vec![
        ("rate_scale", Json::num(2.0)),
        ("offered_rps", Json::num(rate)),
        ("ttft_trend", Json::num(ttft_trend(&run.ttft_secs))),
    ];
    baseline.extend(open_loop_fields(&run));
    Ok(Json::obj(vec![
        ("capacity_rps", Json::num(capacity_rps)),
        ("points", Json::arr(points)),
        ("baseline_no_admission", Json::obj(baseline)),
    ]))
}

/// In-process open-loop run (`--open-loop`, empty `--addr`).
pub fn run_inprocess_open(scenario: Scenario, items: &[WorkItem],
                          seed: u64, scale: f64) -> Result<Report> {
    let mut eng =
        crate::disagg::synthetic_engine(ServingConfig::default())?;
    let run = drive_open_loop(&mut eng, items, scale)?;
    let (mix_domains, mix_tenants) = mix_of(items);
    Ok(Report {
        scenario: scenario.as_str(),
        mode: "inprocess-open",
        seed,
        requests: run.offered,
        errors: run.errors(),
        streamed_tokens: run.streamed_tokens,
        generated_tokens: run.generated_tokens,
        elapsed_secs: run.elapsed_secs,
        ttft: run.ttft_secs.clone(),
        tpot: run.per_token_secs.clone(),
        mix_domains,
        mix_tenants,
        chunking: None,
        first_error: None,
        sessions: None,
        open_loop: Some(Json::obj(open_loop_fields(&run))),
    })
}

/// Count complete SSE token frames in the bytes received so far.
fn count_token_frames(buf: &[u8]) -> usize {
    const PAT: &[u8] = b"data: {\"token\"";
    if buf.len() < PAT.len() {
        return 0;
    }
    buf.windows(PAT.len()).filter(|w| *w == PAT).count()
}

/// How one HTTP request ended, for per-class open-loop accounting.
enum Outcome {
    Done(ReqSample),
    /// 429 — admission refused it; records whether the reply carried
    /// the `Retry-After` header it is required to.
    Shed { retry_after: bool },
    /// 504 pre-stream or a terminal `kind: "timeout"` error frame.
    Timeout,
    Failed(String),
}

/// One streaming request over a raw socket; times SSE frames as they
/// arrive (TTFT = first token frame, TPOT = inter-frame mean) and
/// classifies the ending (done / shed / timeout).
fn sse_request_raw(addr: &str, item: &WorkItem) -> Result<Outcome> {
    let text: String =
        item.prompt.iter().map(|&t| (t as u8) as char).collect();
    let mut fields = vec![
        ("prompt", Json::str(text)),
        ("max_tokens", Json::num(item.max_new as f64)),
        ("stream", Json::Bool(true)),
        ("tenant", Json::str(item.tenant.clone())),
        ("priority", Json::str(item.priority.as_str())),
    ];
    if let Some(d) = &item.domain {
        fields.push(("domain", Json::str(d.clone())));
    }
    if let Some(ms) = item.deadline_ms {
        fields.push(("deadline_ms", Json::num(ms as f64)));
    }
    let body = Json::obj(fields).to_string();
    let mut s = TcpStream::connect(addr)
        .with_context(|| format!("connect {addr}"))?;
    write!(
        s,
        "POST /generate HTTP/1.1\r\nHost: loadgen\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )?;
    s.flush()?;
    let t0 = Instant::now();
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    let mut tokens = 0usize;
    let mut first = None;
    let mut last = Duration::ZERO;
    loop {
        let n = s.read(&mut tmp).context("read stream")?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&tmp[..n]);
        let c = count_token_frames(&buf);
        if c > tokens {
            let now = t0.elapsed();
            first.get_or_insert(now);
            last = now;
            tokens = c;
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let status = head.lines().next().unwrap_or("");
    if status.starts_with("HTTP/1.1 429") {
        return Ok(Outcome::Shed {
            retry_after:
                head.to_ascii_lowercase().contains("retry-after:"),
        });
    }
    if status.starts_with("HTTP/1.1 504") {
        return Ok(Outcome::Timeout);
    }
    if !status.starts_with("HTTP/1.1 200") {
        bail!("non-200 reply: {status:?}");
    }
    if head.contains("\nevent: error\n") {
        if head.contains("\"kind\":\"timeout\"") {
            return Ok(Outcome::Timeout);
        }
        bail!("stream ended with error frame");
    }
    if !head.contains("event: done") {
        bail!("stream ended without done frame");
    }
    let Some(first) = first else {
        bail!("no token frames in stream")
    };
    let tpot = (tokens > 1)
        .then(|| (last - first).as_secs_f64() / (tokens - 1) as f64);
    Ok(Outcome::Done(ReqSample {
        ttft_secs: first.as_secs_f64(),
        tpot_secs: tpot,
        tokens,
    }))
}

/// Closed-loop view of [`sse_request_raw`]: anything but a completed
/// stream is an error.
fn sse_request(addr: &str, item: &WorkItem) -> Result<ReqSample> {
    match sse_request_raw(addr, item)? {
        Outcome::Done(s) => Ok(s),
        Outcome::Shed { .. } => bail!("request shed (429)"),
        Outcome::Timeout => bail!("request timed out (deadline)"),
        Outcome::Failed(e) => bail!("{e}"),
    }
}

/// HTTP open-loop run: every item fires exactly once at its
/// (scale-compressed) arrival timestamp. A worker that falls behind
/// fires immediately — the lateness shows up as server queue delay;
/// dropping arrivals is not an option. Sheds/timeouts are
/// measurements, not errors.
pub fn run_http_open_loop(addr: &str, scenario: Scenario,
                          items: &[WorkItem], seed: u64,
                          concurrency: usize, scale: f64)
                          -> Result<Report> {
    if items.is_empty() {
        bail!("no work items");
    }
    let scale = if scale > 0.0 { scale } else { 1.0 };
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<(&'static str, Outcome)>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    // enough workers that one slow stream cannot stall later arrivals
    let workers = concurrency.max(16).min(items.len());
    std::thread::scope(|sc| {
        for _ in 0..workers {
            sc.spawn(|| {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let item = &items[i];
                    let due =
                        Duration::from_secs_f64(item.arrival / scale);
                    let elapsed = t0.elapsed();
                    if due > elapsed {
                        std::thread::sleep(due - elapsed);
                    }
                    let o = match sse_request_raw(addr, item) {
                        Ok(o) => o,
                        Err(e) => Outcome::Failed(format!("{e:#}")),
                    };
                    local.push((item.priority.as_str(), o));
                }
                out.lock().unwrap().extend(local);
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let mut run = OpenLoopRun {
        offered: items.len(),
        elapsed_secs: elapsed,
        ..Default::default()
    };
    let mut first_error = None;
    let mut sheds_missing_retry_after = 0usize;
    for (cls, o) in out.into_inner().unwrap() {
        run.class(cls).offered += 1;
        match o {
            Outcome::Done(s) => {
                run.completed += 1;
                run.streamed_tokens += s.tokens;
                run.generated_tokens += s.tokens;
                run.ttft_secs.push(s.ttft_secs);
                if let Some(t) = s.tpot_secs {
                    run.per_token_secs.push(t);
                }
                let c = run.class(cls);
                c.completed += 1;
                c.tokens += s.tokens;
                c.ttft.push(s.ttft_secs);
            }
            Outcome::Shed { retry_after } => {
                run.class(cls).shed += 1;
                if !retry_after {
                    sheds_missing_retry_after += 1;
                }
            }
            Outcome::Timeout => run.class(cls).timeout += 1,
            Outcome::Failed(e) => {
                run.class(cls).errors += 1;
                first_error.get_or_insert(e);
            }
        }
    }
    let mut ol = open_loop_fields(&run);
    ol.push(("sheds_missing_retry_after",
             Json::num(sheds_missing_retry_after as f64)));
    let (mix_domains, mix_tenants) = mix_of(items);
    Ok(Report {
        scenario: scenario.as_str(),
        mode: "http-open",
        seed,
        requests: run.offered,
        errors: run.errors(),
        streamed_tokens: run.streamed_tokens,
        generated_tokens: run.generated_tokens,
        elapsed_secs: elapsed,
        ttft: run.ttft_secs.clone(),
        tpot: run.per_token_secs.clone(),
        mix_domains,
        mix_tenants,
        chunking: None,
        first_error,
        sessions: None,
        open_loop: Some(Json::obj(ol)),
    })
}

// ------------------------------------------------- chunking comparison

/// Mean short-request TTFT, in deterministic work units (rows
/// forwarded before the short request's first token), for one long
/// prompt contending with four shorts under the given budget knobs.
fn chunk_probe(step_tokens: usize, prefill_chunk: usize) -> Result<f64> {
    let cfg = ServingConfig {
        step_tokens,
        prefill_chunk,
        exec_threads: 1,
        ..Default::default()
    };
    let mut eng = crate::disagg::synthetic_engine(cfg)?;
    let long: Vec<i32> = (0..256).map(|i| 97 + (i % 26) as i32).collect();
    eng.submit_opts(Some(SYNTH_DOMAIN), long, 2, Sampler::Greedy,
                    "batch", Priority::Standard)?;
    let mut shorts = Vec::new();
    for k in 0..4usize {
        let p: Vec<i32> =
            (0..6).map(|j| 97 + ((k * 7 + j) % 26) as i32).collect();
        shorts.push(eng.submit_opts(Some(SYNTH_DOMAIN), p, 2,
                                    Sampler::Greedy, "chat",
                                    Priority::Standard)?);
    }
    let mut first_wu = std::collections::HashMap::new();
    loop {
        let more = eng.step()?;
        let wu = eng.work_units();
        for (id, _) in eng.take_emitted() {
            first_wu.entry(id).or_insert(wu);
        }
        if !more {
            break;
        }
    }
    let sum: f64 = shorts
        .iter()
        .map(|id| first_wu.get(id).copied().unwrap_or(0) as f64)
        .sum();
    Ok(sum / shorts.len() as f64)
}

/// Chunked vs unchunked prefill, measured clock-free: the acceptance
/// probe behind the `chunking_compare` column of `BENCH_serving.json`.
pub fn chunking_compare() -> Result<Json> {
    let chunked = chunk_probe(64, 64)?;
    let unchunked = chunk_probe(0, 0)?;
    Ok(Json::obj(vec![
        ("unchunked_short_ttft_wu", Json::num(unchunked)),
        ("chunked_short_ttft_wu", Json::num(chunked)),
        ("short_ttft_speedup", Json::num(unchunked / chunked.max(1.0))),
    ]))
}

// ----------------------------------------------------------- the CLI

/// `moska loadgen` entry point (see `main.rs` for the flag set).
pub fn cmd_loadgen(args: &Args) -> Result<()> {
    let name = args.str("scenario")?;
    let scenario = Scenario::from_str(&name)
        .with_context(|| format!("unknown scenario {name:?} (have: \
            rag-shared chat-prefix agent-swarm long-short mixed)"))?;
    let seed = args.usize("seed")? as u64;
    let requests = args.usize("requests")?;
    let seconds = args.f64("seconds")?;
    let concurrency = args.usize("concurrency")?;
    let addr = args.str("addr")?;
    let open_loop = args.flag("open-loop");
    let rate = args.f64("rate")?;
    let rate_scale = args.f64("rate-scale")?;
    // duration-driven runs cycle the item list, so make it deep enough
    // that the mix stays representative
    let n_items = if seconds > 0.0 && !open_loop {
        requests.max(64)
    } else {
        requests
    };
    let mut items = scenario_items(scenario, n_items, seed);
    if open_loop && rate > 0.0 {
        // --rate overrides the scenario's native arrival clock with a
        // single Poisson process (what the overload smoke sweeps)
        items = retime_poisson(&items, rate, seed);
    }
    if let Some(path) = args.get("emit-trace") {
        if !path.is_empty() {
            std::fs::write(
                path, crate::workload::trace_to_json(&items).to_string(),
            )?;
            println!("[loadgen] trace → {path}");
        }
    }
    let mut report = match (addr.is_empty(), open_loop) {
        (true, false) => run_inprocess(scenario, &items, seed)?,
        (true, true) => {
            run_inprocess_open(scenario, &items, seed, rate_scale)?
        }
        (false, false) => {
            run_http(&addr, scenario, &items, seed, concurrency,
                     seconds)?
        }
        (false, true) => {
            run_http_open_loop(&addr, scenario, &items, seed,
                               concurrency, rate_scale)?
        }
    };
    if args.flag("compare-chunking") {
        report.chunking = Some(chunking_compare()?);
    }
    let sweep = if args.flag("sweep") {
        if !addr.is_empty() {
            bail!("--sweep is in-process only (drop --addr)");
        }
        println!("[loadgen] running overload sweep \
                  (calibrate, 0.5x/1x/2x, no-admission baseline)...");
        Some(overload_sweep(requests.max(96), seed)?)
    } else {
        None
    };
    let out = args.str("out")?;
    if let Some(dir) = std::path::Path::new(&out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    // merge over an existing report so independent smokes writing the
    // same file (serving smoke, overload smoke) compose key-wise
    let mut merged = match std::fs::read_to_string(&out)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
    {
        Some(Json::Obj(m)) => m,
        _ => BTreeMap::new(),
    };
    if let Json::Obj(new) = report.to_json() {
        for (k, v) in new {
            merged.insert(k, v);
        }
    }
    if let Some(s) = sweep {
        merged.insert("open_loop_sweep".to_string(), s);
    }
    std::fs::write(&out, Json::Obj(merged).to_string())?;
    println!("[loadgen] {} ({}): {} requests, {} errors, {} streamed \
              tokens in {:.2}s",
             report.scenario, report.mode, report.requests,
             report.errors, report.streamed_tokens, report.elapsed_secs);
    println!("[loadgen] report → {out}");
    if report.errors > 0 {
        if let Some(e) = &report.first_error {
            println!("[loadgen] first error: {e}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_parse_roundtrip() {
        for s in Scenario::all() {
            assert_eq!(Scenario::from_str(s.as_str()), Some(s));
        }
        assert_eq!(Scenario::from_str("RAG-SHARED"),
                   Some(Scenario::RagShared));
        assert_eq!(Scenario::from_str("nope"), None);
    }

    /// Item streams are pure functions of (scenario, n, seed): same
    /// seed → identical items (and identical trace JSON), different
    /// seed → different stream.
    #[test]
    fn scenario_items_deterministic() {
        for s in Scenario::all() {
            let a = scenario_items(s, 40, 7);
            let b = scenario_items(s, 40, 7);
            assert_eq!(a, b);
            let ja = crate::workload::trace_to_json(&a).to_string();
            let jb = crate::workload::trace_to_json(&b).to_string();
            assert_eq!(ja, jb);
            let c = scenario_items(s, 40, 8);
            assert_ne!(a, c, "{s:?} ignores the seed");
        }
    }

    /// Every generated item is servable by the synthetic setup: known
    /// domains, tokenizer-roundtrippable prompt bytes, streaming on,
    /// arrivals monotone.
    #[test]
    fn scenario_items_valid_for_synthetic_serving() {
        for s in Scenario::all() {
            let items = scenario_items(s, 64, 3);
            assert_eq!(items.len(), 64);
            let mut prev = 0.0;
            for w in &items {
                assert!(w.arrival >= prev);
                prev = w.arrival;
                if let Some(d) = &w.domain {
                    assert!(d == SYNTH_DOMAIN || d == SYNTH_DOMAIN_B,
                            "{s:?} uses unknown domain {d}");
                }
                assert!(!w.prompt.is_empty());
                for &t in &w.prompt {
                    assert!((97..123).contains(&t),
                            "{s:?} token {t} not ascii-lowercase");
                }
                assert!(w.max_new >= 1);
                assert!(w.stream);
                assert!(!w.tenant.is_empty());
            }
        }
        // the chat scenario actually shares prefixes across turns
        let items = scenario_items(Scenario::ChatPrefix, 64, 3);
        let mut by_tenant: std::collections::HashMap<&str, Vec<&WorkItem>> =
            std::collections::HashMap::new();
        for w in &items {
            by_tenant.entry(&w.tenant).or_default().push(w);
        }
        let shared = by_tenant.values().any(|ws| {
            ws.len() >= 2 && ws.windows(2).all(|p| {
                p[0].prompt[..12] == p[1].prompt[..12]
            })
        });
        assert!(shared, "no shared prefixes in chat scenario");
    }

    /// SSE frame counting is prefix-safe and ignores non-token frames.
    #[test]
    fn token_frame_counting() {
        assert_eq!(count_token_frames(b""), 0);
        assert_eq!(count_token_frames(b"data: {\"tok"), 0);
        let stream = b"HTTP/1.1 200 OK\r\n\r\n\
                       data: {\"token\":97}\n\n\
                       data: {\"token\":98}\n\n\
                       event: done\ndata: {\"tokens\":[97,98]}\n\n";
        assert_eq!(count_token_frames(stream), 2);
    }

    /// The acceptance probe: chunked prefill must improve short-request
    /// TTFT (in deterministic work units) vs the unchunked baseline
    /// when a long prompt contends for the same engine.
    #[test]
    fn chunking_improves_short_ttft() {
        let chunked = chunk_probe(64, 64).unwrap();
        let unchunked = chunk_probe(0, 0).unwrap();
        assert!(chunked > 0.0 && unchunked > 0.0);
        assert!(
            chunked * 1.2 < unchunked,
            "chunked prefill did not improve short TTFT: \
             chunked={chunked} unchunked={unchunked}"
        );
    }

    /// The chat scenario routes through the sessions API: zero errors,
    /// every non-first turn a reuse hit, and the report carries the
    /// session columns.
    #[test]
    fn chat_prefix_routes_through_sessions() {
        let items = scenario_items(Scenario::ChatPrefix, 16, 5);
        let r = run_inprocess(Scenario::ChatPrefix, &items, 5).unwrap();
        assert_eq!(r.errors, 0);
        assert_eq!(r.requests, 16);
        assert!(r.generated_tokens > 0);
        let s = r.sessions.as_ref().expect("sessions column");
        let conv = s.get("conversations").unwrap().as_usize().unwrap();
        let turns = s.get("turns").unwrap().as_usize().unwrap();
        let hits = s.get("reuse_hits").unwrap().as_usize().unwrap();
        assert!(conv >= 1 && conv <= 4);
        assert_eq!(turns, 16);
        // every turn after a conversation's first reuses parked KV
        assert_eq!(hits, turns - conv);
        assert!(s.get("reused_context_tokens").unwrap()
                    .as_usize().unwrap() > 0);
    }

    /// Open-loop drive completes an uncontended trace with no sheds,
    /// timeouts, or errors, and accounts every arrival per class.
    #[test]
    fn open_loop_drive_uncontended_completes_everything() {
        let mut items = scenario_items(Scenario::Mixed, 12, 9);
        // compress arrivals so the test is fast but still paced
        for w in &mut items {
            w.arrival = w.arrival.min(0.2);
        }
        let mut eng = crate::disagg::synthetic_engine(
            ServingConfig::default()).unwrap();
        let run = drive_open_loop(&mut eng, &items, 1.0).unwrap();
        assert_eq!(run.offered, 12);
        assert_eq!(run.completed, 12);
        assert_eq!(run.shed(), 0);
        assert_eq!(run.timeouts(), 0);
        assert_eq!(run.errors(), 0);
        let per_class_offered: usize =
            run.per_class.values().map(|c| c.offered).sum();
        assert_eq!(per_class_offered, 12);
        assert_eq!(run.ttft_secs.len(), 12);
        assert!(run.generated_tokens > 0);
    }

    /// Poisson retiming is deterministic and strictly rate-scaled.
    #[test]
    fn retime_poisson_deterministic_and_monotone() {
        let items = scenario_items(Scenario::Mixed, 32, 3);
        let a = retime_poisson(&items, 50.0, 3);
        let b = retime_poisson(&items, 50.0, 3);
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[1].arrival > w[0].arrival);
        }
        // only arrivals change
        for (orig, new) in items.iter().zip(&a) {
            assert_eq!(orig.prompt, new.prompt);
            assert_eq!(orig.tenant, new.tenant);
        }
        let span = a.last().unwrap().arrival;
        let rate = 32.0 / span;
        assert!(rate > 20.0 && rate < 120.0, "rate {rate}");
    }

    /// ttft_trend flags a diverging queue and clears a stable one.
    #[test]
    fn ttft_trend_statistic() {
        let stable = vec![0.1; 20];
        assert!((ttft_trend(&stable) - 1.0).abs() < 1e-9);
        let diverging: Vec<f64> =
            (0..20).map(|i| 0.1 + i as f64 * 0.05).collect();
        assert!(ttft_trend(&diverging) > 1.5);
        assert_eq!(ttft_trend(&[0.1, 0.2]), 1.0); // too few samples
    }

    /// In-process runs are seed-deterministic in every count column.
    #[test]
    fn inprocess_run_deterministic_counts() {
        let items = scenario_items(Scenario::RagShared, 12, 5);
        let a = run_inprocess(Scenario::RagShared, &items, 5).unwrap();
        let b = run_inprocess(Scenario::RagShared, &items, 5).unwrap();
        assert_eq!(a.requests, 12);
        assert_eq!(a.errors, 0);
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.streamed_tokens, b.streamed_tokens);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert!(a.streamed_tokens > 0);
        assert_eq!(a.mix_domains, b.mix_domains);
        assert_eq!(a.mix_tenants, b.mix_tenants);
        let j = a.to_json();
        assert_eq!(j.get("errors").unwrap().as_usize().unwrap(), 0);
        assert!(j.get("ttft_p50_ms").unwrap().as_f64().unwrap()
                    .is_finite());
        assert!(j.get("mix").unwrap().get("tenants").is_ok());
    }
}
