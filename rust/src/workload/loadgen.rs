//! `moska loadgen` — deterministic traffic generator for the serving
//! loop.
//!
//! Scenario mixes model the paper's serving workloads over the
//! synthetic shared store: RAG fleets over shared corpora
//! (`rag-shared`), multi-turn chat with shared prompt prefixes
//! (`chat-prefix`), agent swarms hammering one domain (`agent-swarm`),
//! a long-prompt/short-prompt interleaving stress (`long-short`), and
//! a round-robin of all four (`mixed`). Item streams are pure
//! functions of (scenario, n, seed) — identical across runs and
//! platforms — so traces can be recorded, diffed, and replayed.
//!
//! Two drive modes share the same items:
//! * **in-process** (`--addr ''`): closed-loop against
//!   [`synthetic_engine`][crate::disagg::synthetic_engine]; TTFT/TPOT
//!   come from engine lifecycle timings, token/mix counts are
//!   seed-deterministic.
//! * **HTTP** (`--addr host:port`): closed-loop worker threads POST
//!   `/generate` with `"stream": true` and time the SSE frames off the
//!   wire — TTFT is the first `data:` frame, TPOT the inter-frame
//!   mean.
//!
//! Reports land in `bench_out/BENCH_serving.json`; `scripts/ci.sh`
//! gates on zero errors, nonzero streamed tokens, and finite latency
//! quantiles. `--compare-chunking` adds the chunked-vs-unchunked
//! short-request TTFT probe measured in deterministic work units.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::ServingConfig;
use crate::disagg::{SYNTH_DOMAIN, SYNTH_DOMAIN_B};
use crate::model::sampling::Sampler;
use crate::scheduler::Priority;
use crate::util::bench::Stats;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::WorkItem;

/// Named traffic mix (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    RagShared,
    ChatPrefix,
    AgentSwarm,
    LongShort,
    Mixed,
}

impl Scenario {
    pub fn from_str(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "rag-shared" => Some(Scenario::RagShared),
            "chat-prefix" => Some(Scenario::ChatPrefix),
            "agent-swarm" => Some(Scenario::AgentSwarm),
            "long-short" => Some(Scenario::LongShort),
            "mixed" => Some(Scenario::Mixed),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Scenario::RagShared => "rag-shared",
            Scenario::ChatPrefix => "chat-prefix",
            Scenario::AgentSwarm => "agent-swarm",
            Scenario::LongShort => "long-short",
            Scenario::Mixed => "mixed",
        }
    }

    pub fn all() -> [Scenario; 5] {
        [Scenario::RagShared, Scenario::ChatPrefix, Scenario::AgentSwarm,
         Scenario::LongShort, Scenario::Mixed]
    }
}

/// One prompt token: lowercase ASCII so the byte-level tokenizer
/// round-trips it through the HTTP JSON body unchanged.
fn tok(rng: &mut Rng) -> i32 {
    97 + rng.below(26) as i32
}

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| tok(rng)).collect()
}

/// Deterministic item stream: a pure function of (scenario, n, seed).
pub fn scenario_items(s: Scenario, n: usize, seed: u64) -> Vec<WorkItem> {
    let mut rng = Rng::new(seed);
    // chat conversations share fixed per-seed prefixes (drawn up front
    // so every turn of a conversation reuses the same bytes)
    let prefixes: Vec<Vec<i32>> =
        (0..4).map(|_| prompt(&mut rng, 12)).collect();
    let mut clock = 0.0;
    (0..n)
        .map(|i| {
            let kind = match s {
                Scenario::Mixed => {
                    [Scenario::RagShared, Scenario::ChatPrefix,
                     Scenario::AgentSwarm, Scenario::LongShort][i % 4]
                }
                k => k,
            };
            let rate = match kind {
                Scenario::AgentSwarm => 100.0,
                _ => 20.0,
            };
            clock += rng.exponential(rate);
            let mut w = match kind {
                Scenario::RagShared => {
                    // two RAG tenants over the two shared corpora
                    let domain = if i % 4 == 3 {
                        SYNTH_DOMAIN_B
                    } else {
                        SYNTH_DOMAIN
                    };
                    let plen = rng.range(8, 25);
                    let p = prompt(&mut rng, plen);
                    let mut w = WorkItem::basic(
                        clock, Some(domain.into()), p, rng.range(4, 9),
                    );
                    w.tenant = if i % 2 == 0 { "rag-a" } else { "rag-b" }
                        .to_string();
                    w
                }
                Scenario::ChatPrefix => {
                    // turn = shared conversation prefix + fresh suffix
                    let conv = rng.range(0, prefixes.len());
                    let mut p = prefixes[conv].clone();
                    let extra = rng.range(4, 9);
                    p.extend((0..extra).map(|_| tok(&mut rng)));
                    let mut w = WorkItem::basic(
                        clock, None, p, rng.range(4, 11),
                    );
                    w.tenant = format!("chat-{conv}");
                    w.priority = Priority::Interactive;
                    w
                }
                Scenario::AgentSwarm => {
                    // one tenant, one corpus, short bursty requests
                    let p = prompt(&mut rng, rng.range(4, 9));
                    let mut w = WorkItem::basic(
                        clock, Some(SYNTH_DOMAIN.into()), p,
                        rng.range(2, 5),
                    );
                    w.tenant = "swarm".to_string();
                    w.priority = Priority::Batch;
                    w
                }
                Scenario::LongShort => {
                    // a long batch prompt every 8th item, interactive
                    // shorts in between — the chunked-prefill stress
                    if i % 8 == 0 {
                        let p = prompt(&mut rng, rng.range(96, 129));
                        let mut w = WorkItem::basic(
                            clock, Some(SYNTH_DOMAIN.into()), p, 4,
                        );
                        w.tenant = "batch".to_string();
                        w.priority = Priority::Batch;
                        w
                    } else {
                        let p = prompt(&mut rng, rng.range(4, 9));
                        let mut w = WorkItem::basic(
                            clock, Some(SYNTH_DOMAIN.into()), p, 4,
                        );
                        w.tenant = "chat".to_string();
                        w.priority = Priority::Interactive;
                        w
                    }
                }
                Scenario::Mixed => unreachable!(),
            };
            w.stream = true;
            w
        })
        .collect()
}

/// One request's client-side timings.
struct ReqSample {
    ttft_secs: f64,
    tpot_secs: Option<f64>,
    tokens: usize,
}

/// Aggregated loadgen run, serialized to `BENCH_serving.json`.
pub struct Report {
    pub scenario: &'static str,
    pub mode: &'static str,
    pub seed: u64,
    pub requests: usize,
    pub errors: usize,
    pub streamed_tokens: usize,
    pub generated_tokens: usize,
    pub elapsed_secs: f64,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    mix_domains: BTreeMap<String, usize>,
    mix_tenants: BTreeMap<String, usize>,
    pub chunking: Option<Json>,
    pub first_error: Option<String>,
}

fn quantiles(samples: &[f64]) -> (f64, f64) {
    if samples.is_empty() {
        return (0.0, 0.0);
    }
    let s = Stats::from_samples(
        samples.iter().map(|&v| Duration::from_secs_f64(v)).collect(),
    );
    (s.p50.as_secs_f64(), s.p99.as_secs_f64())
}

/// The seed-deterministic request mix of an item stream (what the
/// determinism tests diff across runs).
fn mix_of(items: &[WorkItem])
          -> (BTreeMap<String, usize>, BTreeMap<String, usize>) {
    let mut domains = BTreeMap::new();
    let mut tenants = BTreeMap::new();
    for w in items {
        let d = w.domain.clone().unwrap_or_else(|| "unique".to_string());
        *domains.entry(d).or_insert(0) += 1;
        *tenants.entry(w.tenant.clone()).or_insert(0) += 1;
    }
    (domains, tenants)
}

impl Report {
    pub fn to_json(&self) -> Json {
        let (ttft_p50, ttft_p99) = quantiles(&self.ttft);
        let (tpot_p50, tpot_p99) = quantiles(&self.tpot);
        let count_map = |m: &BTreeMap<String, usize>| {
            Json::obj(
                m.iter()
                    .map(|(k, v)| (k.as_str(), Json::num(*v as f64)))
                    .collect(),
            )
        };
        let goodput = if self.elapsed_secs > 0.0 {
            (self.requests - self.errors) as f64 / self.elapsed_secs
        } else {
            0.0
        };
        let mut fields = vec![
            ("scenario", Json::str(self.scenario)),
            ("mode", Json::str(self.mode)),
            ("seed", Json::num(self.seed as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("streamed_tokens", Json::num(self.streamed_tokens as f64)),
            ("generated_tokens", Json::num(self.generated_tokens as f64)),
            ("elapsed_secs", Json::num(self.elapsed_secs)),
            ("ttft_p50_ms", Json::num(ttft_p50 * 1e3)),
            ("ttft_p99_ms", Json::num(ttft_p99 * 1e3)),
            ("tpot_p50_ms", Json::num(tpot_p50 * 1e3)),
            ("tpot_p99_ms", Json::num(tpot_p99 * 1e3)),
            ("goodput_rps", Json::num(goodput)),
            ("mix", Json::obj(vec![
                ("domains", count_map(&self.mix_domains)),
                ("tenants", count_map(&self.mix_tenants)),
            ])),
        ];
        if let Some(c) = &self.chunking {
            fields.push(("chunking_compare", c.clone()));
        }
        if let Some(e) = &self.first_error {
            fields.push(("first_error", Json::str(e.clone())));
        }
        Json::obj(fields)
    }
}

/// Closed-loop in-process run: submit every item against a fresh
/// synthetic engine, drain to completion, report lifecycle timings.
/// Token and mix columns are pure functions of (scenario, seed, n).
pub fn run_inprocess(scenario: Scenario, items: &[WorkItem], seed: u64)
                     -> Result<Report> {
    let mut eng =
        crate::disagg::synthetic_engine(ServingConfig::default())?;
    let t0 = Instant::now();
    for w in items {
        eng.submit_opts(w.domain.as_deref(), w.prompt.clone(), w.max_new,
                        Sampler::Greedy, &w.tenant, w.priority)?;
    }
    let results = eng.run_to_completion()?;
    let elapsed = t0.elapsed().as_secs_f64();
    let streamed = eng.take_emitted().len();
    let mut ttft = Vec::new();
    let mut tpot = Vec::new();
    let mut generated = 0usize;
    for r in &results {
        ttft.push(r.queue_secs + r.prefill_secs);
        if r.tokens.len() > 1 {
            tpot.push(r.decode_secs / (r.tokens.len() - 1) as f64);
        }
        generated += r.tokens.len();
    }
    let (mix_domains, mix_tenants) = mix_of(items);
    Ok(Report {
        scenario: scenario.as_str(),
        mode: "inprocess",
        seed,
        requests: results.len(),
        errors: items.len() - results.len(),
        streamed_tokens: streamed,
        generated_tokens: generated,
        elapsed_secs: elapsed,
        ttft,
        tpot,
        mix_domains,
        mix_tenants,
        chunking: None,
        first_error: None,
    })
}

/// Closed-loop HTTP run: `concurrency` workers each stream one request
/// at a time over raw sockets until the deadline (or every item once
/// when `seconds == 0`).
pub fn run_http(addr: &str, scenario: Scenario, items: &[WorkItem],
                seed: u64, concurrency: usize, seconds: f64)
                -> Result<Report> {
    if items.is_empty() {
        bail!("no work items");
    }
    let next = AtomicUsize::new(0);
    let deadline = (seconds > 0.0)
        .then(|| Instant::now() + Duration::from_secs_f64(seconds));
    let out: Mutex<Vec<Result<ReqSample>>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    std::thread::scope(|sc| {
        for _ in 0..concurrency.max(1) {
            sc.spawn(|| {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let stop = match deadline {
                        Some(d) => Instant::now() >= d,
                        None => i >= items.len(),
                    };
                    if stop {
                        break;
                    }
                    local.push(sse_request(addr, &items[i % items.len()]));
                }
                out.lock().unwrap().extend(local);
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let samples = out.into_inner().unwrap();
    let mut ttft = Vec::new();
    let mut tpot = Vec::new();
    let mut streamed = 0usize;
    let mut errors = 0usize;
    let mut first_error = None;
    let requests = samples.len();
    for s in samples {
        match s {
            Ok(s) => {
                ttft.push(s.ttft_secs);
                if let Some(t) = s.tpot_secs {
                    tpot.push(t);
                }
                streamed += s.tokens;
            }
            Err(e) => {
                errors += 1;
                first_error.get_or_insert_with(|| format!("{e:#}"));
            }
        }
    }
    let (mix_domains, mix_tenants) = mix_of(items);
    Ok(Report {
        scenario: scenario.as_str(),
        mode: "http",
        seed,
        requests,
        errors,
        streamed_tokens: streamed,
        generated_tokens: streamed,
        elapsed_secs: elapsed,
        ttft,
        tpot,
        mix_domains,
        mix_tenants,
        chunking: None,
        first_error,
    })
}

/// Count complete SSE token frames in the bytes received so far.
fn count_token_frames(buf: &[u8]) -> usize {
    const PAT: &[u8] = b"data: {\"token\"";
    if buf.len() < PAT.len() {
        return 0;
    }
    buf.windows(PAT.len()).filter(|w| *w == PAT).count()
}

/// One streaming request over a raw socket; times SSE frames as they
/// arrive (TTFT = first token frame, TPOT = inter-frame mean).
fn sse_request(addr: &str, item: &WorkItem) -> Result<ReqSample> {
    let text: String =
        item.prompt.iter().map(|&t| (t as u8) as char).collect();
    let mut fields = vec![
        ("prompt", Json::str(text)),
        ("max_tokens", Json::num(item.max_new as f64)),
        ("stream", Json::Bool(true)),
        ("tenant", Json::str(item.tenant.clone())),
        ("priority", Json::str(item.priority.as_str())),
    ];
    if let Some(d) = &item.domain {
        fields.push(("domain", Json::str(d.clone())));
    }
    let body = Json::obj(fields).to_string();
    let mut s = TcpStream::connect(addr)
        .with_context(|| format!("connect {addr}"))?;
    write!(
        s,
        "POST /generate HTTP/1.1\r\nHost: loadgen\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )?;
    s.flush()?;
    let t0 = Instant::now();
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    let mut tokens = 0usize;
    let mut first = None;
    let mut last = Duration::ZERO;
    loop {
        let n = s.read(&mut tmp).context("read stream")?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&tmp[..n]);
        let c = count_token_frames(&buf);
        if c > tokens {
            let now = t0.elapsed();
            first.get_or_insert(now);
            last = now;
            tokens = c;
        }
    }
    let head = String::from_utf8_lossy(&buf);
    if !head.starts_with("HTTP/1.1 200") {
        bail!("non-200 reply: {:?}", head.lines().next().unwrap_or(""));
    }
    if !head.contains("event: done") {
        bail!("stream ended without done frame");
    }
    let Some(first) = first else {
        bail!("no token frames in stream")
    };
    let tpot = (tokens > 1)
        .then(|| (last - first).as_secs_f64() / (tokens - 1) as f64);
    Ok(ReqSample { ttft_secs: first.as_secs_f64(), tpot_secs: tpot,
                   tokens })
}

// ------------------------------------------------- chunking comparison

/// Mean short-request TTFT, in deterministic work units (rows
/// forwarded before the short request's first token), for one long
/// prompt contending with four shorts under the given budget knobs.
fn chunk_probe(step_tokens: usize, prefill_chunk: usize) -> Result<f64> {
    let cfg = ServingConfig {
        step_tokens,
        prefill_chunk,
        exec_threads: 1,
        ..Default::default()
    };
    let mut eng = crate::disagg::synthetic_engine(cfg)?;
    let long: Vec<i32> = (0..256).map(|i| 97 + (i % 26) as i32).collect();
    eng.submit_opts(Some(SYNTH_DOMAIN), long, 2, Sampler::Greedy,
                    "batch", Priority::Standard)?;
    let mut shorts = Vec::new();
    for k in 0..4usize {
        let p: Vec<i32> =
            (0..6).map(|j| 97 + ((k * 7 + j) % 26) as i32).collect();
        shorts.push(eng.submit_opts(Some(SYNTH_DOMAIN), p, 2,
                                    Sampler::Greedy, "chat",
                                    Priority::Standard)?);
    }
    let mut first_wu = std::collections::HashMap::new();
    loop {
        let more = eng.step()?;
        let wu = eng.work_units();
        for (id, _) in eng.take_emitted() {
            first_wu.entry(id).or_insert(wu);
        }
        if !more {
            break;
        }
    }
    let sum: f64 = shorts
        .iter()
        .map(|id| first_wu.get(id).copied().unwrap_or(0) as f64)
        .sum();
    Ok(sum / shorts.len() as f64)
}

/// Chunked vs unchunked prefill, measured clock-free: the acceptance
/// probe behind the `chunking_compare` column of `BENCH_serving.json`.
pub fn chunking_compare() -> Result<Json> {
    let chunked = chunk_probe(64, 64)?;
    let unchunked = chunk_probe(0, 0)?;
    Ok(Json::obj(vec![
        ("unchunked_short_ttft_wu", Json::num(unchunked)),
        ("chunked_short_ttft_wu", Json::num(chunked)),
        ("short_ttft_speedup", Json::num(unchunked / chunked.max(1.0))),
    ]))
}

// ----------------------------------------------------------- the CLI

/// `moska loadgen` entry point (see `main.rs` for the flag set).
pub fn cmd_loadgen(args: &Args) -> Result<()> {
    let name = args.str("scenario")?;
    let scenario = Scenario::from_str(&name)
        .with_context(|| format!("unknown scenario {name:?} (have: \
            rag-shared chat-prefix agent-swarm long-short mixed)"))?;
    let seed = args.usize("seed")? as u64;
    let requests = args.usize("requests")?;
    let seconds = args.f64("seconds")?;
    let concurrency = args.usize("concurrency")?;
    let addr = args.str("addr")?;
    // duration-driven runs cycle the item list, so make it deep enough
    // that the mix stays representative
    let n_items = if seconds > 0.0 { requests.max(64) } else { requests };
    let items = scenario_items(scenario, n_items, seed);
    if let Some(path) = args.get("emit-trace") {
        if !path.is_empty() {
            std::fs::write(
                path, crate::workload::trace_to_json(&items).to_string(),
            )?;
            println!("[loadgen] trace → {path}");
        }
    }
    let mut report = if addr.is_empty() {
        run_inprocess(scenario, &items, seed)?
    } else {
        run_http(&addr, scenario, &items, seed, concurrency, seconds)?
    };
    if args.flag("compare-chunking") {
        report.chunking = Some(chunking_compare()?);
    }
    let out = args.str("out")?;
    if let Some(dir) = std::path::Path::new(&out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let j = report.to_json();
    std::fs::write(&out, j.to_string())?;
    println!("[loadgen] {} ({}): {} requests, {} errors, {} streamed \
              tokens in {:.2}s",
             report.scenario, report.mode, report.requests,
             report.errors, report.streamed_tokens, report.elapsed_secs);
    println!("[loadgen] report → {out}");
    if report.errors > 0 {
        if let Some(e) = &report.first_error {
            println!("[loadgen] first error: {e}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_parse_roundtrip() {
        for s in Scenario::all() {
            assert_eq!(Scenario::from_str(s.as_str()), Some(s));
        }
        assert_eq!(Scenario::from_str("RAG-SHARED"),
                   Some(Scenario::RagShared));
        assert_eq!(Scenario::from_str("nope"), None);
    }

    /// Item streams are pure functions of (scenario, n, seed): same
    /// seed → identical items (and identical trace JSON), different
    /// seed → different stream.
    #[test]
    fn scenario_items_deterministic() {
        for s in Scenario::all() {
            let a = scenario_items(s, 40, 7);
            let b = scenario_items(s, 40, 7);
            assert_eq!(a, b);
            let ja = crate::workload::trace_to_json(&a).to_string();
            let jb = crate::workload::trace_to_json(&b).to_string();
            assert_eq!(ja, jb);
            let c = scenario_items(s, 40, 8);
            assert_ne!(a, c, "{s:?} ignores the seed");
        }
    }

    /// Every generated item is servable by the synthetic setup: known
    /// domains, tokenizer-roundtrippable prompt bytes, streaming on,
    /// arrivals monotone.
    #[test]
    fn scenario_items_valid_for_synthetic_serving() {
        for s in Scenario::all() {
            let items = scenario_items(s, 64, 3);
            assert_eq!(items.len(), 64);
            let mut prev = 0.0;
            for w in &items {
                assert!(w.arrival >= prev);
                prev = w.arrival;
                if let Some(d) = &w.domain {
                    assert!(d == SYNTH_DOMAIN || d == SYNTH_DOMAIN_B,
                            "{s:?} uses unknown domain {d}");
                }
                assert!(!w.prompt.is_empty());
                for &t in &w.prompt {
                    assert!((97..123).contains(&t),
                            "{s:?} token {t} not ascii-lowercase");
                }
                assert!(w.max_new >= 1);
                assert!(w.stream);
                assert!(!w.tenant.is_empty());
            }
        }
        // the chat scenario actually shares prefixes across turns
        let items = scenario_items(Scenario::ChatPrefix, 64, 3);
        let mut by_tenant: std::collections::HashMap<&str, Vec<&WorkItem>> =
            std::collections::HashMap::new();
        for w in &items {
            by_tenant.entry(&w.tenant).or_default().push(w);
        }
        let shared = by_tenant.values().any(|ws| {
            ws.len() >= 2 && ws.windows(2).all(|p| {
                p[0].prompt[..12] == p[1].prompt[..12]
            })
        });
        assert!(shared, "no shared prefixes in chat scenario");
    }

    /// SSE frame counting is prefix-safe and ignores non-token frames.
    #[test]
    fn token_frame_counting() {
        assert_eq!(count_token_frames(b""), 0);
        assert_eq!(count_token_frames(b"data: {\"tok"), 0);
        let stream = b"HTTP/1.1 200 OK\r\n\r\n\
                       data: {\"token\":97}\n\n\
                       data: {\"token\":98}\n\n\
                       event: done\ndata: {\"tokens\":[97,98]}\n\n";
        assert_eq!(count_token_frames(stream), 2);
    }

    /// The acceptance probe: chunked prefill must improve short-request
    /// TTFT (in deterministic work units) vs the unchunked baseline
    /// when a long prompt contends for the same engine.
    #[test]
    fn chunking_improves_short_ttft() {
        let chunked = chunk_probe(64, 64).unwrap();
        let unchunked = chunk_probe(0, 0).unwrap();
        assert!(chunked > 0.0 && unchunked > 0.0);
        assert!(
            chunked * 1.2 < unchunked,
            "chunked prefill did not improve short TTFT: \
             chunked={chunked} unchunked={unchunked}"
        );
    }

    /// In-process runs are seed-deterministic in every count column.
    #[test]
    fn inprocess_run_deterministic_counts() {
        let items = scenario_items(Scenario::RagShared, 12, 5);
        let a = run_inprocess(Scenario::RagShared, &items, 5).unwrap();
        let b = run_inprocess(Scenario::RagShared, &items, 5).unwrap();
        assert_eq!(a.requests, 12);
        assert_eq!(a.errors, 0);
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.streamed_tokens, b.streamed_tokens);
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert!(a.streamed_tokens > 0);
        assert_eq!(a.mix_domains, b.mix_domains);
        assert_eq!(a.mix_tenants, b.mix_tenants);
        let j = a.to_json();
        assert_eq!(j.get("errors").unwrap().as_usize().unwrap(), 0);
        assert!(j.get("ttft_p50_ms").unwrap().as_f64().unwrap()
                    .is_finite());
        assert!(j.get("mix").unwrap().get("tenants").is_ok());
    }
}
