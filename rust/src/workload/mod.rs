//! Synthetic serving workloads (paper §IV setup, laptop scale).
//!
//! The paper's workload: a large shared context per request plus a smaller
//! unique context, with a target SLO per request. The generator produces
//! request streams with Zipf-skewed domain popularity (context *sharing* is
//! the controlled variable), Poisson arrivals, and configurable
//! prompt/generation lengths. Traces are deterministic given a seed and
//! can be recorded/replayed as JSON.

use anyhow::{Context, Result};

use crate::scheduler::Priority;
use crate::util::json::Json;
use crate::util::rng::Rng;

pub mod loadgen;

/// One generated request (engine-agnostic description).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkItem {
    /// Arrival time offset (seconds from trace start).
    pub arrival: f64,
    /// Shared domain name, or None for a no-sharing request.
    pub domain: Option<String>,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Fair-share tenant this request bills to.
    pub tenant: String,
    pub priority: Priority,
    /// Ask the server for SSE token streaming.
    pub stream: bool,
    /// Optional per-request end-to-end deadline (engine cancels the
    /// request past it, lifecycle records a timeout). `None` = the
    /// serving config's class default, if any.
    pub deadline_ms: Option<u64>,
}

impl WorkItem {
    /// The non-scheduling defaults shared by every construction site.
    pub fn basic(arrival: f64, domain: Option<String>, prompt: Vec<i32>,
                 max_new: usize) -> WorkItem {
        WorkItem {
            arrival,
            domain,
            prompt,
            max_new,
            tenant: "default".to_string(),
            priority: Priority::Standard,
            stream: false,
            deadline_ms: None,
        }
    }
}

/// Workload shape knobs.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub domains: Vec<String>,
    /// Zipf exponent for domain popularity (0 = uniform).
    pub domain_skew: f64,
    /// Fraction of requests with no shared context.
    pub unique_only_frac: f64,
    pub prompt_len: (usize, usize),
    pub max_new: (usize, usize),
    /// Mean arrival rate (requests/sec) for the Poisson process.
    pub rate: f64,
    pub vocab: usize,
}

impl Default for WorkloadConfig {
    fn default() -> WorkloadConfig {
        WorkloadConfig {
            domains: vec!["legal".into(), "medical".into(), "code".into()],
            domain_skew: 1.1,
            unique_only_frac: 0.1,
            prompt_len: (8, 24),
            max_new: (8, 32),
            rate: 50.0,
            vocab: 256,
        }
    }
}

/// Deterministic request-stream generator.
pub struct Generator {
    cfg: WorkloadConfig,
    rng: Rng,
    clock: f64,
}

impl Generator {
    pub fn new(cfg: WorkloadConfig, seed: u64) -> Generator {
        Generator { cfg, rng: Rng::new(seed), clock: 0.0 }
    }

    pub fn next_item(&mut self) -> WorkItem {
        let c = &self.cfg;
        self.clock += self.rng.exponential(c.rate);
        let domain = if self.rng.f64() < c.unique_only_frac
            || c.domains.is_empty()
        {
            None
        } else if c.domain_skew <= 0.0 {
            Some(c.domains[self.rng.range(0, c.domains.len())].clone())
        } else {
            Some(c.domains[self.rng.zipf(c.domains.len(), c.domain_skew)]
                 .clone())
        };
        let plen = self.rng.range(c.prompt_len.0, c.prompt_len.1 + 1);
        let prompt =
            (0..plen).map(|_| self.rng.below(c.vocab as u64) as i32).collect();
        let max_new = self.rng.range(c.max_new.0, c.max_new.1 + 1);
        WorkItem::basic(self.clock, domain, prompt, max_new)
    }

    pub fn take(&mut self, n: usize) -> Vec<WorkItem> {
        (0..n).map(|_| self.next_item()).collect()
    }
}

/// Serialize a trace to JSON (record) / parse it back (replay).
pub fn trace_to_json(items: &[WorkItem]) -> Json {
    Json::arr(
        items
            .iter()
            .map(|w| {
                let mut fields = vec![
                    ("arrival", Json::num(w.arrival)),
                    ("domain", match &w.domain {
                        Some(d) => Json::str(d.clone()),
                        None => Json::Null,
                    }),
                    ("prompt", Json::arr(
                        w.prompt.iter().map(|&t| Json::num(t as f64)).collect(),
                    )),
                    ("max_new", Json::num(w.max_new as f64)),
                ];
                // scheduling fields are emitted only when non-default so
                // pre-existing traces stay byte-stable
                if w.tenant != "default" {
                    fields.push(("tenant", Json::str(w.tenant.clone())));
                }
                if w.priority != Priority::Standard {
                    fields.push(("priority", Json::str(w.priority.as_str())));
                }
                if w.stream {
                    fields.push(("stream", Json::Bool(true)));
                }
                if let Some(ms) = w.deadline_ms {
                    fields.push(("deadline_ms", Json::num(ms as f64)));
                }
                Json::obj(fields)
            })
            .collect(),
    )
}

pub fn trace_from_json(j: &Json) -> Result<Vec<WorkItem>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            Ok(WorkItem {
                arrival: e.get("arrival")?.as_f64()?,
                domain: match e.get("domain")? {
                    Json::Null => None,
                    d => Some(d.as_str()?.to_string()),
                },
                prompt: e.get("prompt")?.as_i32_vec()?,
                max_new: e.get("max_new")?.as_usize()?,
                tenant: match e.opt("tenant") {
                    Some(t) => t.as_str()?.to_string(),
                    None => "default".to_string(),
                },
                priority: match e.opt("priority") {
                    Some(p) => {
                        let s = p.as_str()?;
                        Priority::from_str(s)
                            .with_context(|| format!("bad priority {s:?}"))?
                    }
                    None => Priority::Standard,
                },
                stream: match e.opt("stream") {
                    Some(b) => b.as_bool()?,
                    None => false,
                },
                deadline_ms: match e.opt("deadline_ms") {
                    Some(v) => Some(v.as_usize()? as u64),
                    None => None,
                },
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Generator::new(WorkloadConfig::default(), 9);
        let mut b = Generator::new(WorkloadConfig::default(), 9);
        assert_eq!(a.take(20), b.take(20));
    }

    #[test]
    fn arrivals_monotone_and_rate_sane() {
        let mut g = Generator::new(
            WorkloadConfig { rate: 100.0, ..Default::default() }, 1,
        );
        let items = g.take(500);
        for w in items.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = items.last().unwrap().arrival;
        let rate = 500.0 / span;
        assert!((rate - 100.0).abs() < 20.0, "rate {rate}");
    }

    #[test]
    fn zipf_skews_domains() {
        let mut g = Generator::new(
            WorkloadConfig {
                domain_skew: 1.5,
                unique_only_frac: 0.0,
                ..Default::default()
            },
            2,
        );
        let mut counts = std::collections::HashMap::new();
        for w in g.take(1000) {
            *counts.entry(w.domain.unwrap()).or_insert(0usize) += 1;
        }
        assert!(counts["legal"] > counts["code"], "{counts:?}");
    }

    #[test]
    fn prompt_lengths_in_range() {
        let mut g = Generator::new(WorkloadConfig::default(), 3);
        for w in g.take(100) {
            assert!((8..=24).contains(&w.prompt.len()));
            assert!((8..=32).contains(&w.max_new));
            for &t in &w.prompt {
                assert!((0..256).contains(&t));
            }
        }
    }

    #[test]
    fn trace_roundtrip() {
        let mut g = Generator::new(WorkloadConfig::default(), 4);
        let items = g.take(10);
        let j = trace_to_json(&items);
        let back = trace_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(items, back);
    }

    /// Non-default scheduling fields survive the JSON roundtrip, and
    /// default ones are omitted from the serialized form entirely.
    #[test]
    fn trace_roundtrip_scheduling_fields() {
        let mut w = WorkItem::basic(0.5, Some("bench".into()),
                                    vec![97, 98, 99], 4);
        w.tenant = "rag-a".to_string();
        w.priority = Priority::Interactive;
        w.stream = true;
        w.deadline_ms = Some(1500);
        let plain = WorkItem::basic(0.75, None, vec![100], 2);
        let items = vec![w, plain];
        let s = trace_to_json(&items).to_string();
        assert!(s.contains("\"tenant\""));
        assert!(s.contains("\"priority\""));
        assert!(s.contains("\"stream\""));
        assert!(s.contains("\"deadline_ms\""));
        // the defaulted item contributes none of the optional keys
        assert_eq!(s.matches("\"tenant\"").count(), 1);
        assert_eq!(s.matches("\"deadline_ms\"").count(), 1);
        let back = trace_from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(items, back);
        assert!(trace_from_json(
            &Json::parse("[{\"arrival\":0,\"domain\":null,\"prompt\":[1],\
                           \"max_new\":1,\"priority\":\"nope\"}]").unwrap()
        ).is_err());
    }
}
