//! Attention orchestration: shared (batched GEMM) + unique (per-request)
//! paths, LSE merging, and the gather/scatter between them.
//!
//! Exactness guarantee: with dense routing, `shared_attention` ∪
//! `unique_attention` merged per query equals monolithic softmax attention
//! over the full context — the flash decomposition property tested at
//! every layer of the stack (python `test_chunked_equals_full`, native
//! `chunked_equals_monolithic`, and the engine goldens).

use anyhow::Result;

use crate::batcher::BatchStats;
use crate::kvcache::paged::{PagePool, RequestKv};
use crate::kvcache::shared_store::DomainCache;
use crate::plan::{exec_gemm_calls, exec_unique_spans, plan_gemm_calls,
                  plan_unique_spans};
use crate::router::ChunkSet;
use crate::runtime::arena::TensorArena;
use crate::runtime::native::{self, Partials};
use crate::runtime::simd::Kernels;
use crate::runtime::Backend;
use crate::tensor::Tensor;

impl Partials {
    /// Rows `[start, end)` of these partials.
    pub fn slice_rows(&self, start: usize, end: usize) -> Partials {
        Partials {
            o: self.o.slice0(start, end),
            m: self.m.slice0(start, end),
            l: self.l.slice0(start, end),
        }
    }
}

/// Merge any number of partials (native LSE algebra, arity-N).
pub fn merge_many(parts: &[Partials]) -> Partials {
    assert!(!parts.is_empty());
    let mut acc = parts[0].clone();
    for p in &parts[1..] {
        acc = native::merge2(&acc, p);
    }
    acc
}

/// Accumulator for per-row partial merging (scatter side of batching).
///
/// Stores one flat `[B,H,dh]` partial set and merges rows **in place**
/// (§Perf opt 1: the previous per-row `Vec<Partials>` version allocated
/// three tensors per merge; this one allocates nothing after creation).
pub struct RowAccumulator {
    acc: Partials,
    /// Kernel flavor for the merge/finalize tails — callers on a
    /// backend hot path set it to `backend.kernels()` so one backend =
    /// one flavor end to end; the default is the process-global flavor.
    kern: &'static Kernels,
}

impl RowAccumulator {
    pub fn identity(b: usize, h: usize, dh: usize) -> RowAccumulator {
        RowAccumulator {
            acc: Partials::identity(b, h, dh),
            kern: Kernels::global(),
        }
    }

    /// Accumulator whose identity partials come from the step arena
    /// (decode plan-executor path) — same contents, recycled storage.
    pub fn from_arena(arena: &mut TensorArena, b: usize, h: usize,
                      dh: usize) -> RowAccumulator {
        RowAccumulator {
            acc: arena.take_partials(b, h, dh),
            kern: Kernels::global(),
        }
    }

    /// Run this accumulator's merge/finalize tails on an explicit
    /// kernel flavor (builder style).
    pub fn with_kernel(mut self, kern: &'static Kernels)
                       -> RowAccumulator {
        self.kern = kern;
        self
    }

    /// Return the accumulator's storage to the arena.
    pub fn recycle_into(self, arena: &mut TensorArena) {
        arena.recycle_partials(self.acc);
    }

    /// [`Self::finalize`] into an arena-owned output tensor.
    pub fn finalize_with(&self, arena: &mut TensorArena) -> Tensor {
        let shape = self.acc.o.shape().to_vec();
        let mut out = arena.take_tensor(&shape);
        native::finalize_into_kern(self.kern, &self.acc, out.as_f32_mut());
        out
    }

    /// Merge batch partials back into their owning rows.
    pub fn scatter(&mut self, batch_rows: &[usize], p: &Partials) {
        for (i, &slot) in batch_rows.iter().enumerate() {
            native::merge2_row_into_kern(self.kern, &mut self.acc, slot, p,
                                         i);
        }
    }

    /// The accumulated partials (read access).
    pub fn partials(&self) -> &Partials {
        &self.acc
    }

    /// Merge row 0 of a single-row partial into row `i`.
    pub fn merge_row(&mut self, i: usize, p: &Partials) {
        native::merge2_row_into_kern(self.kern, &mut self.acc, i, p, 0);
    }

    /// Merge row `src_idx` of `p` into row `i`.
    pub fn merge_row_from(&mut self, i: usize, p: &Partials,
                          src_idx: usize) {
        native::merge2_row_into_kern(self.kern, &mut self.acc, i, p,
                                     src_idx);
    }

    /// Merge another accumulator's rows in (e.g. unique ∪ shared).
    pub fn merge_from(&mut self, other: &RowAccumulator) {
        let b = self.acc.batch();
        assert_eq!(b, other.acc.batch());
        for i in 0..b {
            native::merge2_row_into_kern(self.kern, &mut self.acc, i,
                                         &other.acc, i);
        }
    }

    /// Normalize all rows into the final `[B, H, dh]` attention output.
    pub fn finalize(&self) -> Tensor {
        let shape = self.acc.o.shape().to_vec();
        let mut out = vec![0f32; shape.iter().product()];
        native::finalize_into_kern(self.kern, &self.acc, &mut out);
        Tensor::f32(&shape, out)
    }
}

/// Shared-KV attention for one layer: gather rows per routed chunk,
/// execute the batched GEMM kernel, scatter partials back.
///
/// `q` `[B,H,dh]`, `q_pos[B]`, `sets[B]` (chunk ids). When
/// `position_independent` is set the chunk is attended at its *local*
/// positions (Universal MoSKA composition mode, approximate); otherwise
/// `k_base = chunk_index * chunk_tokens` (exact prefix semantics).
/// `arena` stages the gather/concat buffers and kernel partials —
/// prefill passes the engine's step arena, closing the last
/// plain-allocation path; `None` falls back to heap allocation.
#[allow(clippy::too_many_arguments)]
pub fn shared_attention(
    backend: &dyn Backend,
    domain: &DomainCache,
    layer: usize,
    q: &Tensor,
    q_pos: &[i32],
    sets: &[ChunkSet],
    acc: &mut RowAccumulator,
    position_independent: bool,
    max_batch: usize,
    arena: Option<&mut TensorArena>,
) -> Result<BatchStats> {
    // plan (batch forming + §Perf-opt-2 run coalescing) then execute —
    // the same two primitives the decode StepPlan uses, so prefill and
    // decode share one batching implementation
    let (calls, stats) = plan_gemm_calls(
        sets, max_batch, domain.chunk, &domain.chunk_bases,
        backend.max_attn_tokens(), position_independent,
    );
    exec_gemm_calls(backend, domain, layer, q, q_pos, &calls, acc, arena)?;
    Ok(stats)
}

/// Unique-KV attention for one request's query rows (one layer): iterate
/// its pages — on real hardware these are the memory-bound GEMV ops the
/// paper leaves on the Unique node. `arena` as in [`shared_attention`];
/// the returned [`Partials`] are arena-owned when one is passed, so the
/// caller recycles them after merging.
pub fn unique_attention(
    backend: &dyn Backend,
    pool: &PagePool,
    kv: &RequestKv,
    layer: usize,
    q: &Tensor,
    q_pos: &[i32],
    arena: Option<&mut TensorArena>,
) -> Result<Partials> {
    // plan the page spans (coalesced up to the kernel's max K/V length)
    // from the layer's in-flight written length, then execute — the
    // decode StepPlan precomputes the same spans once per step
    let spans = plan_unique_spans(kv.layer_len(layer), kv.start_pos,
                                  pool.chunk(), backend.max_attn_tokens());
    exec_unique_spans(backend, pool, kv, layer, q, q_pos, &spans, arena)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::runtime::NativeBackend;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let mut d = vec![0f32; shape.iter().product()];
        rng.fill_normal_f32(&mut d);
        Tensor::f32(shape, d)
    }

    fn fake_domain(rng: &mut Rng, n_chunks: usize, chunk: usize) -> DomainCache {
        let layers = (0..2)
            .map(|_| crate::kvcache::shared_store::LayerChunks {
                chunks: (0..n_chunks)
                    .map(|_| (rand_t(rng, &[chunk, 2, 16]),
                              rand_t(rng, &[chunk, 2, 16])))
                    .collect(),
                embs: rand_t(rng, &[n_chunks, 2, 16]),
            })
            .collect();
        DomainCache {
            name: "test".into(),
            tokens: vec![0; n_chunks * chunk],
            n_tokens: n_chunks * chunk,
            n_chunks,
            chunk,
            layers,
            chunk_ids: (0..n_chunks as u64).collect(),
            chunk_bases: (0..n_chunks).map(|c| (c * chunk) as i32).collect(),
        }
    }

    #[test]
    fn shared_attention_equals_direct() {
        // batching across rows must not change any row's result
        let be = NativeBackend::new(ModelConfig::tiny(), 64);
        let mut rng = Rng::new(0);
        let dom = fake_domain(&mut rng, 4, 64);
        let b = 3;
        let q = rand_t(&mut rng, &[b, 4, 16]);
        let q_pos = vec![1000, 500, 300];
        let sets: Vec<ChunkSet> = vec![vec![0, 2], vec![1], vec![0, 1, 3]];

        let mut acc = RowAccumulator::identity(b, 4, 16);
        shared_attention(&be, &dom, 0, &q, &q_pos, &sets, &mut acc, false,
                         32, None)
            .unwrap();
        let got = acc.finalize();

        // arena-staged prefill path must not change a bit
        let mut arena = TensorArena::new();
        let mut acc2 = RowAccumulator::from_arena(&mut arena, b, 4, 16);
        shared_attention(&be, &dom, 0, &q, &q_pos, &sets, &mut acc2, false,
                         32, Some(&mut arena))
            .unwrap();
        assert_eq!(acc2.finalize(), got);
        acc2.recycle_into(&mut arena);

        // direct per-row computation
        for (row, set) in sets.iter().enumerate() {
            let qr = Tensor::f32(&[1, 4, 16], q.index0(row).to_vec());
            let mut parts = Vec::new();
            for &c in set {
                let (k, v) = dom.chunk_kv(0, c);
                parts.push(
                    be.chunk_attn(&qr, k, v, &[q_pos[row]],
                                  (c * 64) as i32, 64)
                        .unwrap(),
                );
            }
            let want = native::finalize(&merge_many(&parts));
            let gr = got.slice0(row, row + 1).reshaped(&[1, 4, 16]);
            assert!(gr.max_abs_diff(&want) < 1e-5, "row {row}");
        }
    }

    /// Delegating backend with a configurable `max_attn_tokens`, to force
    /// specific run-coalescing splits in `unique_attention`.
    struct CappedBackend {
        inner: NativeBackend,
        cap: usize,
    }

    impl Backend for CappedBackend {
        fn name(&self) -> &'static str {
            "capped-native"
        }
        fn model(&self) -> &ModelConfig {
            self.inner.model()
        }
        fn chunk_size(&self) -> usize {
            self.inner.chunk_size()
        }
        fn max_attn_tokens(&self) -> usize {
            self.cap
        }
        fn embed(&self, tokens: &Tensor, emb: &Tensor) -> Result<Tensor> {
            self.inner.embed(tokens, emb)
        }
        fn qkv(&self, x: &Tensor, attn_norm: &Tensor, wq: &Tensor,
               wk: &Tensor, wv: &Tensor, pos: &[i32])
               -> Result<(Tensor, Tensor, Tensor)> {
            self.inner.qkv(x, attn_norm, wq, wk, wv, pos)
        }
        fn chunk_attn(&self, q: &Tensor, k: &Tensor, v: &Tensor,
                      q_pos: &[i32], k_base: i32, valid: i32)
                      -> Result<Partials> {
            self.inner.chunk_attn(q, k, v, q_pos, k_base, valid)
        }
        fn post(&self, attn_o: &Tensor, x: &Tensor, wo: &Tensor,
                ffn_norm: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor)
                -> Result<Tensor> {
            self.inner.post(attn_o, x, wo, ffn_norm, w1, w3, w2)
        }
        fn lm_head(&self, x: &Tensor, final_norm: &Tensor, w_lm: &Tensor)
                   -> Result<Tensor> {
            self.inner.lm_head(x, final_norm, w_lm)
        }
        fn router(&self, q: &Tensor, embs: &Tensor) -> Result<Tensor> {
            self.inner.router(q, embs)
        }
        fn merge2(&self, a: &Partials, b: &Partials) -> Result<Partials> {
            self.inner.merge2(a, b)
        }
        fn exec_plan(&self, plan: &crate::plan::StepPlan, x: Tensor,
                     ctx: &mut crate::plan::PlanExecCtx<'_>)
                     -> Result<crate::plan::PlanExecOut> {
            crate::plan::exec::execute_plan(self, plan, x, ctx)
        }
    }

    /// Run coalescing across paged unique KV must be exact for every run
    /// length, including a partially-filled last page mid-run.
    #[test]
    fn unique_attention_coalescing_partial_last_page() {
        let chunk = 8;
        let (hkv, dh, h) = (2, 16, 4);
        let mut rng = Rng::new(21);
        let mut pool = crate::kvcache::paged::PagePool::new(
            16, chunk, hkv, dh,
        );
        // 20 tokens → pages of 8, 8, and a partially-filled 4
        let n = 20;
        let k_all = rand_t(&mut rng, &[n, hkv, dh]);
        let v_all = rand_t(&mut rng, &[n, hkv, dh]);
        let mut kv = crate::kvcache::paged::RequestKv::new(1, 0);
        kv.append(&mut pool, &[(k_all.clone(), v_all.clone())]).unwrap();
        assert_eq!(kv.page_valid(2, chunk), 4, "last page partially filled");

        let q = rand_t(&mut rng, &[1, h, dh]);
        for q_pos in [1000, 18, 10, 3] {
            // reference: one monolithic call over the full 20 tokens
            let whole = crate::runtime::native::chunk_attn(
                &q, &k_all, &v_all, &[q_pos], 0, n as i32,
            );
            let want = native::finalize(&whole);
            // cap 16 → runs of (page0+page1) then (partial page2);
            // cap 8 → three single-page runs; cap 1024 → one run
            for cap in [8usize, 16, 1024] {
                // threads=1: no pool spawn per iteration; the kernel work
                // here is below the parallel floor anyway
                let be = CappedBackend {
                    inner: NativeBackend::with_threads(
                        ModelConfig::tiny(), chunk, 1,
                    ),
                    cap,
                };
                let got = unique_attention(&be, &pool, &kv, 0, &q, &[q_pos],
                                           None)
                    .unwrap();
                let got = native::finalize(&got);
                let d = got.max_abs_diff(&want);
                assert!(d < 1e-5, "cap={cap} q_pos={q_pos} diff={d}");
                // arena path: bit-identical to the allocating path
                let mut arena = TensorArena::new();
                let ga = unique_attention(&be, &pool, &kv, 0, &q, &[q_pos],
                                          Some(&mut arena))
                    .unwrap();
                assert_eq!(native::finalize(&ga), got);
                arena.recycle_partials(ga);
            }
        }
    }

    #[test]
    fn merge_many_matches_pairwise() {
        let be = NativeBackend::new(ModelConfig::tiny(), 64);
        let mut rng = Rng::new(1);
        let q = rand_t(&mut rng, &[2, 4, 16]);
        let parts: Vec<Partials> = (0..4)
            .map(|i| {
                let k = rand_t(&mut rng, &[64, 2, 16]);
                let v = rand_t(&mut rng, &[64, 2, 16]);
                be.chunk_attn(&q, &k, &v, &[10_000, 10_000], i * 64, 64)
                    .unwrap()
            })
            .collect();
        let a = merge_many(&parts);
        let mut b = parts[0].clone();
        for p in &parts[1..] {
            b = native::merge2(&b, p);
        }
        assert!(a.o.max_abs_diff(&b.o) < 1e-6);
    }
}
