//! Attention orchestration: shared (batched GEMM) + unique (per-request)
//! paths, LSE merging, and the gather/scatter between them.
//!
//! Exactness guarantee: with dense routing, `shared_attention` ∪
//! `unique_attention` merged per query equals monolithic softmax attention
//! over the full context — the flash decomposition property tested at
//! every layer of the stack (python `test_chunked_equals_full`, native
//! `chunked_equals_monolithic`, and the engine goldens).

use anyhow::Result;

use crate::batcher::{form_batches, BatchStats};
use crate::kvcache::paged::{PagePool, RequestKv};
use crate::kvcache::shared_store::DomainCache;
use crate::router::ChunkSet;
use crate::runtime::native::{self, Partials};
use crate::runtime::Backend;
use crate::tensor::Tensor;

impl Partials {
    /// Rows `[start, end)` of these partials.
    pub fn slice_rows(&self, start: usize, end: usize) -> Partials {
        Partials {
            o: self.o.slice0(start, end),
            m: self.m.slice0(start, end),
            l: self.l.slice0(start, end),
        }
    }
}

/// Merge any number of partials (native LSE algebra, arity-N).
pub fn merge_many(parts: &[Partials]) -> Partials {
    assert!(!parts.is_empty());
    let mut acc = parts[0].clone();
    for p in &parts[1..] {
        acc = native::merge2(&acc, p);
    }
    acc
}

/// Accumulator for per-row partial merging (scatter side of batching).
///
/// Stores one flat `[B,H,dh]` partial set and merges rows **in place**
/// (§Perf opt 1: the previous per-row `Vec<Partials>` version allocated
/// three tensors per merge; this one allocates nothing after creation).
pub struct RowAccumulator {
    acc: Partials,
}

impl RowAccumulator {
    pub fn identity(b: usize, h: usize, dh: usize) -> RowAccumulator {
        RowAccumulator { acc: Partials::identity(b, h, dh) }
    }

    /// Merge batch partials back into their owning rows.
    pub fn scatter(&mut self, batch_rows: &[usize], p: &Partials) {
        for (i, &slot) in batch_rows.iter().enumerate() {
            native::merge2_row_into(&mut self.acc, slot, p, i);
        }
    }

    /// The accumulated partials (read access).
    pub fn partials(&self) -> &Partials {
        &self.acc
    }

    /// Extract per-row partials (fabric boundaries, e.g. disagg RPC).
    pub fn into_rows(self) -> Vec<Partials> {
        let b = self.acc.batch();
        (0..b).map(|i| self.acc.slice_rows(i, i + 1)).collect()
    }

    /// Merge row 0 of a single-row partial into row `i`.
    pub fn merge_row(&mut self, i: usize, p: &Partials) {
        native::merge2_row_into(&mut self.acc, i, p, 0);
    }

    /// Merge row `src_idx` of `p` into row `i`.
    pub fn merge_row_from(&mut self, i: usize, p: &Partials,
                          src_idx: usize) {
        native::merge2_row_into(&mut self.acc, i, p, src_idx);
    }

    /// Merge another accumulator's rows in (e.g. unique ∪ shared).
    pub fn merge_from(&mut self, other: &RowAccumulator) {
        let b = self.acc.batch();
        assert_eq!(b, other.acc.batch());
        for i in 0..b {
            native::merge2_row_into(&mut self.acc, i, &other.acc, i);
        }
    }

    /// Normalize all rows into the final `[B, H, dh]` attention output.
    pub fn finalize(&self) -> Tensor {
        native::finalize(&self.acc)
    }
}

/// Shared-KV attention for one layer: gather rows per routed chunk,
/// execute the batched GEMM kernel, scatter partials back.
///
/// `q` `[B,H,dh]`, `q_pos[B]`, `sets[B]` (chunk ids). When
/// `position_independent` is set the chunk is attended at its *local*
/// positions (Universal MoSKA composition mode, approximate); otherwise
/// `k_base = chunk_index * chunk_tokens` (exact prefix semantics).
#[allow(clippy::too_many_arguments)]
pub fn shared_attention(
    backend: &dyn Backend,
    domain: &DomainCache,
    layer: usize,
    q: &Tensor,
    q_pos: &[i32],
    sets: &[ChunkSet],
    acc: &mut RowAccumulator,
    position_independent: bool,
    max_batch: usize,
) -> Result<BatchStats> {
    let chunk = domain.chunk;
    let (batches, mut stats) = form_batches(sets, max_batch);
    stats.chunk_reads = batches.len();

    // §Perf opt 2 — run coalescing: consecutive chunks attended by the
    // SAME query rows with contiguous base positions are concatenated
    // into one kernel call (dense routing turns 64 calls into 4).
    // Position-independent mode attends each chunk at local positions,
    // so runs there would change semantics — skip coalescing.
    let max_tokens = backend.max_attn_tokens();
    let max_run = if position_independent { 1 } else { max_tokens / chunk };

    let mut i = 0;
    while i < batches.len() {
        let mut j = i + 1;
        while j < batches.len()
            && j - i < max_run
            && batches[j].chunk == batches[j - 1].chunk + 1
            && batches[j].rows == batches[i].rows
            && domain.chunk_base(batches[j].chunk)
                == domain.chunk_base(batches[j - 1].chunk) + chunk as i32
        {
            j += 1;
        }
        let run = &batches[i..j];
        let rows = &run[0].rows;
        let n = rows.len();
        let (_, h, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);

        // gather query rows once per run
        let mut qb = Vec::with_capacity(n * h * dh);
        let mut pb = Vec::with_capacity(n);
        for &slot in rows {
            qb.extend_from_slice(q.index0(slot));
            pb.push(q_pos[slot]);
        }
        let qb = Tensor::f32(&[n, h, dh], qb);

        // K/V for the run: zero-copy for single chunks, concat for runs
        let run_tokens = run.len() * chunk;
        let (p, k_base_used) = if run.len() == 1 {
            let (k, v) = domain.chunk_kv(layer, run[0].chunk);
            let (k_base, pos_override): (i32, Option<Vec<i32>>) =
                if position_independent {
                    (0, Some(vec![chunk as i32; n]))
                } else {
                    (domain.chunk_base(run[0].chunk), None)
                };
            let pos_ref = pos_override.as_deref().unwrap_or(&pb);
            // auto-dispatch: a 1-2 row sparse batch is GEMV-sized work
            // below the PJRT dispatch floor; real GEMM batches (the
            // paper's regime) exceed the threshold and stay compiled
            (backend.chunk_attn_auto(&qb, k, v, pos_ref, k_base,
                                     chunk as i32)?, k_base)
        } else {
            let ks: Vec<&Tensor> =
                run.iter().map(|b| domain.chunk_kv(layer, b.chunk).0).collect();
            let vs: Vec<&Tensor> =
                run.iter().map(|b| domain.chunk_kv(layer, b.chunk).1).collect();
            let k = Tensor::concat0(&ks);
            let v = Tensor::concat0(&vs);
            let k_base = domain.chunk_base(run[0].chunk);
            (backend.chunk_attn_auto(&qb, &k, &v, &pb, k_base,
                                     run_tokens as i32)?, k_base)
        };
        let _ = k_base_used;
        acc.scatter(rows, &p);
        stats.exec_calls += 1;
        i = j;
    }
    Ok(stats)
}

/// Unique-KV attention for one request's query rows (one layer): iterate
/// its pages — on real hardware these are the memory-bound GEMV ops the
/// paper leaves on the Unique node.
pub fn unique_attention(
    backend: &dyn Backend,
    pool: &PagePool,
    kv: &RequestKv,
    layer: usize,
    q: &Tensor,
    q_pos: &[i32],
) -> Result<Partials> {
    let (b, h, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let chunk = pool.chunk();
    let mut acc = Partials::identity(b, h, dh);
    // coalesce consecutive pages into one call, up to the kernel's max
    // K/V length (pages are positionally contiguous by construction)
    let max_run = (backend.max_attn_tokens() / chunk).max(1);
    let n_pages = kv.page_count_layer(layer);
    let mut p = 0;
    while p < n_pages {
        let run_end = (p + max_run).min(n_pages);
        let mut valid_total = 0i32;
        let mut last = p;
        for pp in p..run_end {
            let v = kv.page_valid_layer(layer, pp, chunk);
            if v == 0 {
                break;
            }
            valid_total += v;
            last = pp + 1;
        }
        if valid_total == 0 {
            break;
        }
        let k_base = kv.page_base(p, chunk);
        // `chunk_attn_auto`: decode-time unique attention is tiny GEMV
        // work and dispatches natively below the PJRT-overhead floor
        let part = if last - p == 1 {
            let page = pool.get(kv.pages[layer][p]);
            backend.chunk_attn_auto(q, &page.k, &page.v, q_pos, k_base,
                                    valid_total)?
        } else {
            let ks: Vec<&Tensor> = (p..last)
                .map(|pp| &pool.get(kv.pages[layer][pp]).k)
                .collect();
            let vs: Vec<&Tensor> = (p..last)
                .map(|pp| &pool.get(kv.pages[layer][pp]).v)
                .collect();
            let k = Tensor::concat0(&ks);
            let v = Tensor::concat0(&vs);
            backend.chunk_attn_auto(q, &k, &v, q_pos, k_base, valid_total)?
        };
        acc = native::merge2(&acc, &part);
        p = last;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::runtime::NativeBackend;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let mut d = vec![0f32; shape.iter().product()];
        rng.fill_normal_f32(&mut d);
        Tensor::f32(shape, d)
    }

    fn fake_domain(rng: &mut Rng, n_chunks: usize, chunk: usize) -> DomainCache {
        let layers = (0..2)
            .map(|_| crate::kvcache::shared_store::LayerChunks {
                chunks: (0..n_chunks)
                    .map(|_| (rand_t(rng, &[chunk, 2, 16]),
                              rand_t(rng, &[chunk, 2, 16])))
                    .collect(),
                embs: rand_t(rng, &[n_chunks, 2, 16]),
            })
            .collect();
        DomainCache {
            name: "test".into(),
            tokens: vec![0; n_chunks * chunk],
            n_chunks,
            chunk,
            layers,
            chunk_ids: (0..n_chunks as u64).collect(),
            chunk_bases: (0..n_chunks).map(|c| (c * chunk) as i32).collect(),
        }
    }

    #[test]
    fn shared_attention_equals_direct() {
        // batching across rows must not change any row's result
        let be = NativeBackend::new(ModelConfig::tiny(), 64);
        let mut rng = Rng::new(0);
        let dom = fake_domain(&mut rng, 4, 64);
        let b = 3;
        let q = rand_t(&mut rng, &[b, 4, 16]);
        let q_pos = vec![1000, 500, 300];
        let sets: Vec<ChunkSet> = vec![vec![0, 2], vec![1], vec![0, 1, 3]];

        let mut acc = RowAccumulator::identity(b, 4, 16);
        shared_attention(&be, &dom, 0, &q, &q_pos, &sets, &mut acc, false, 32)
            .unwrap();
        let got = acc.finalize();

        // direct per-row computation
        for (row, set) in sets.iter().enumerate() {
            let qr = Tensor::f32(&[1, 4, 16], q.index0(row).to_vec());
            let mut parts = Vec::new();
            for &c in set {
                let (k, v) = dom.chunk_kv(0, c);
                parts.push(
                    be.chunk_attn(&qr, k, v, &[q_pos[row]],
                                  (c * 64) as i32, 64)
                        .unwrap(),
                );
            }
            let want = native::finalize(&merge_many(&parts));
            let gr = got.slice0(row, row + 1).reshaped(&[1, 4, 16]);
            assert!(gr.max_abs_diff(&want) < 1e-5, "row {row}");
        }
    }

    /// Delegating backend with a configurable `max_attn_tokens`, to force
    /// specific run-coalescing splits in `unique_attention`.
    struct CappedBackend {
        inner: NativeBackend,
        cap: usize,
    }

    impl Backend for CappedBackend {
        fn name(&self) -> &'static str {
            "capped-native"
        }
        fn model(&self) -> &ModelConfig {
            self.inner.model()
        }
        fn chunk_size(&self) -> usize {
            self.inner.chunk_size()
        }
        fn max_attn_tokens(&self) -> usize {
            self.cap
        }
        fn embed(&self, tokens: &Tensor, emb: &Tensor) -> Result<Tensor> {
            self.inner.embed(tokens, emb)
        }
        fn qkv(&self, x: &Tensor, attn_norm: &Tensor, wq: &Tensor,
               wk: &Tensor, wv: &Tensor, pos: &[i32])
               -> Result<(Tensor, Tensor, Tensor)> {
            self.inner.qkv(x, attn_norm, wq, wk, wv, pos)
        }
        fn chunk_attn(&self, q: &Tensor, k: &Tensor, v: &Tensor,
                      q_pos: &[i32], k_base: i32, valid: i32)
                      -> Result<Partials> {
            self.inner.chunk_attn(q, k, v, q_pos, k_base, valid)
        }
        fn post(&self, attn_o: &Tensor, x: &Tensor, wo: &Tensor,
                ffn_norm: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor)
                -> Result<Tensor> {
            self.inner.post(attn_o, x, wo, ffn_norm, w1, w3, w2)
        }
        fn lm_head(&self, x: &Tensor, final_norm: &Tensor, w_lm: &Tensor)
                   -> Result<Tensor> {
            self.inner.lm_head(x, final_norm, w_lm)
        }
        fn router(&self, q: &Tensor, embs: &Tensor) -> Result<Tensor> {
            self.inner.router(q, embs)
        }
        fn merge2(&self, a: &Partials, b: &Partials) -> Result<Partials> {
            self.inner.merge2(a, b)
        }
    }

    /// Run coalescing across paged unique KV must be exact for every run
    /// length, including a partially-filled last page mid-run.
    #[test]
    fn unique_attention_coalescing_partial_last_page() {
        let chunk = 8;
        let (hkv, dh, h) = (2, 16, 4);
        let mut rng = Rng::new(21);
        let mut pool = crate::kvcache::paged::PagePool::new(
            16, chunk, hkv, dh,
        );
        // 20 tokens → pages of 8, 8, and a partially-filled 4
        let n = 20;
        let k_all = rand_t(&mut rng, &[n, hkv, dh]);
        let v_all = rand_t(&mut rng, &[n, hkv, dh]);
        let mut kv = crate::kvcache::paged::RequestKv::new(1, 0);
        kv.append(&mut pool, &[(k_all.clone(), v_all.clone())]).unwrap();
        assert_eq!(kv.page_valid(2, chunk), 4, "last page partially filled");

        let q = rand_t(&mut rng, &[1, h, dh]);
        for q_pos in [1000, 18, 10, 3] {
            // reference: one monolithic call over the full 20 tokens
            let whole = crate::runtime::native::chunk_attn(
                &q, &k_all, &v_all, &[q_pos], 0, n as i32,
            );
            let want = native::finalize(&whole);
            // cap 16 → runs of (page0+page1) then (partial page2);
            // cap 8 → three single-page runs; cap 1024 → one run
            for cap in [8usize, 16, 1024] {
                // threads=1: no pool spawn per iteration; the kernel work
                // here is below the parallel floor anyway
                let be = CappedBackend {
                    inner: NativeBackend::with_threads(
                        ModelConfig::tiny(), chunk, 1,
                    ),
                    cap,
                };
                let got = unique_attention(&be, &pool, &kv, 0, &q, &[q_pos])
                    .unwrap();
                let got = native::finalize(&got);
                let d = got.max_abs_diff(&want);
                assert!(d < 1e-5, "cap={cap} q_pos={q_pos} diff={d}");
            }
        }
    }

    #[test]
    fn merge_many_matches_pairwise() {
        let be = NativeBackend::new(ModelConfig::tiny(), 64);
        let mut rng = Rng::new(1);
        let q = rand_t(&mut rng, &[2, 4, 16]);
        let parts: Vec<Partials> = (0..4)
            .map(|i| {
                let k = rand_t(&mut rng, &[64, 2, 16]);
                let v = rand_t(&mut rng, &[64, 2, 16]);
                be.chunk_attn(&q, &k, &v, &[10_000, 10_000], i * 64, 64)
                    .unwrap()
            })
            .collect();
        let a = merge_many(&parts);
        let mut b = parts[0].clone();
        for p in &parts[1..] {
            b = native::merge2(&b, p);
        }
        assert!(a.o.max_abs_diff(&b.o) < 1e-6);
    }
}
