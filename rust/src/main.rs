//! `moska` launcher — subcommand dispatch for the serving system, the
//! disaggregated simulation, and the paper's analytical figures.
//!
//! ```text
//! moska serve       [--addr 127.0.0.1:8080] [--top-k 4] [--synthetic]
//! moska loadgen     [--addr 127.0.0.1:8080] [--scenario rag-shared]
//! moska demo        [--requests 8] [--steps 16] [--domain legal]
//! moska figures     [--out bench_out]
//! moska disagg      [--batches 1,8,64,256] [--remote 127.0.0.1:7070]
//!                   [--shards a:7070,b:7071] [--domains legal,code]
//! moska shared-node [--addr 127.0.0.1:7070] [--synthetic] [--domains a,b]
//! moska artifacts-info
//! ```

use moska::util::cli::Cli;

fn main() {
    moska::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) if !c.starts_with('-') => (c.clone(), r.to_vec()),
        _ => {
            eprint!("{}", usage());
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "serve" => cmd_serve(&rest),
        "loadgen" => cmd_loadgen(&rest),
        "demo" => cmd_demo(&rest),
        "figures" => cmd_figures(&rest),
        "disagg" => cmd_disagg(&rest),
        "shared-node" => cmd_shared_node(&rest),
        "replay" => cmd_replay(&rest),
        "trace" => cmd_trace(&rest),
        "artifacts-info" => cmd_artifacts_info(&rest),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            return;
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "moska — Mixture of Shared KV Attention serving system\n\n\
     Commands:\n\
     \x20 serve            run the HTTP serving endpoint\n\
     \x20 loadgen          drive a serving endpoint with scenario traffic\n\
     \x20 demo             run a batched-decode demo on the tiny model\n\
     \x20 figures          regenerate the paper's figures (analytical model)\n\
     \x20 disagg           run the disaggregated two-node simulation\n\
     \x20 shared-node      serve the Shared KV store to remote disagg runs\n\
     \x20 replay           open-loop Poisson workload replay\n\
     \x20 artifacts-info   list compiled artifacts + manifest summary\n\
     \x20 help             this text\n\n\
     Run `moska <command> --help` for command options.\n"
        .to_string()
}

fn cmd_serve(argv: &[String]) -> moska::Result<()> {
    let args = Cli::new("moska serve", "HTTP serving endpoint")
        .opt("addr", "127.0.0.1:8080", "listen address")
        .opt("artifacts", "", "artifacts dir (default: auto-discover)")
        .opt("top-k", "0", "router top-k (0 = dense/exact)")
        .opt("backend", "xla", "xla | native")
        .opt("threads", "0", "native exec threads (0 = auto, 1 = serial)")
        .opt("kernel", "auto",
             "kernel flavor: auto | simd | scalar | lanes8 | avx512 (MOSKA_KERNEL)")
        .opt("kv-dtype", "auto",
             "K/V storage dtype: auto | f32 | f16 | bf16 | int8 (MOSKA_KV_DTYPE)")
        .opt("max-batch", "32", "max decode batch")
        .opt("config", "", "JSON config file (flags override it)")
        .opt("step-tokens", "",
             "per-tick token budget shared by decode + prefill \
              (0 = unbudgeted; default from config, 256)")
        .opt("prefill-chunk", "",
             "prefill tokens per chunk (0 = whole prompts; default \
              from config, 32)")
        .opt("preempt", "", "preemption policy: hold | recompute")
        .opt("admission", "",
             "SLO-aware admission: off | on | HIGH,LOW[,MAX_QUEUE] \
              watermarks (default from config)")
        .opt("deadline-ms", "",
             "per-class end-to-end deadline defaults, e.g. \
              'interactive=2000,batch=60000'")
        .opt("ttft-deadline-ms", "",
             "per-class time-to-first-token deadline defaults")
        .opt("trace", "",
             "write a Chrome-trace span timeline here (flushed every 5s)")
        .flag("synthetic",
              "synthetic weights + bench domains (no artifacts)")
        .parse_from(argv)?;
    moska::server::run_server(&args)
}

fn cmd_loadgen(argv: &[String]) -> moska::Result<()> {
    let args = Cli::new("moska loadgen",
                        "deterministic serving-loop traffic generator")
        .opt("addr", "",
             "serving endpoint (empty = closed-loop in-process engine)")
        .opt("scenario", "rag-shared",
             "rag-shared | chat-prefix | agent-swarm | long-short | mixed")
        .opt("requests", "32", "work items to generate (and run, when \
              --seconds is 0)")
        .opt("seconds", "0",
             "run duration; 0 = run each item exactly once")
        .opt("concurrency", "4", "HTTP worker connections")
        .opt("seed", "7", "scenario stream seed")
        .opt("out", "bench_out/BENCH_serving.json", "report path")
        .opt("emit-trace", "",
             "also write the WorkItem trace JSON here")
        .opt("rate", "0",
             "open-loop: re-time arrivals as one Poisson process at \
              this rate (req/s; 0 = keep scenario arrivals)")
        .opt("rate-scale", "1.0",
             "open-loop: compress arrival timestamps by this factor \
              (2.0 = offer twice as fast)")
        .flag("open-loop",
              "honor arrival timestamps; sheds/timeouts are measured, \
               not retried")
        .flag("sweep",
              "in-process overload sweep (0.5x/1x/2x capacity + \
               no-admission baseline) → open_loop_sweep")
        .flag("compare-chunking",
              "add the chunked-vs-unchunked short-TTFT probe to the report")
        .parse_from(argv)?;
    moska::workload::loadgen::cmd_loadgen(&args)
}

fn cmd_demo(argv: &[String]) -> moska::Result<()> {
    let args = Cli::new("moska demo", "batched decode demo")
        .opt("artifacts", "", "artifacts dir (default: auto-discover)")
        .opt("requests", "8", "concurrent requests")
        .opt("steps", "16", "decode steps per request")
        .opt("domain", "legal", "shared domain (legal|medical|code|none)")
        .opt("top-k", "0", "router top-k (0 = dense/exact)")
        .opt("backend", "xla", "xla | native")
        .opt("threads", "0", "native exec threads (0 = auto, 1 = serial)")
        .opt("kernel", "auto",
             "kernel flavor: auto | simd | scalar | lanes8 | avx512 (MOSKA_KERNEL)")
        .opt("kv-dtype", "auto",
             "K/V storage dtype: auto | f32 | f16 | bf16 | int8 (MOSKA_KV_DTYPE)")
        .parse_from(argv)?;
    moska::engine::run_demo(&args)
}

fn cmd_figures(argv: &[String]) -> moska::Result<()> {
    let args = Cli::new("moska figures", "paper figure regeneration")
        .opt("out", "bench_out", "output directory for CSVs")
        .parse_from(argv)?;
    moska::analytical::run_all_figures(&args)
}

fn cmd_disagg(argv: &[String]) -> moska::Result<()> {
    let args = Cli::new("moska disagg", "disaggregated two-node simulation")
        .opt("artifacts", "", "artifacts dir (default: auto-discover)")
        .opt("batches", "1,4,16,64", "comma-separated batch sizes")
        .opt("steps", "8", "decode steps per batch point")
        .opt("backend", "native", "xla | native")
        .opt("threads", "0", "native exec threads (0 = auto, 1 = serial)")
        .opt("kernel", "auto",
             "kernel flavor: auto | simd | scalar | lanes8 | avx512 (MOSKA_KERNEL)")
        .opt("kv-dtype", "auto",
             "K/V storage dtype: auto | f32 | f16 | bf16 | int8 (MOSKA_KV_DTYPE)")
        .opt("remote", "",
             "shared-node address (empty = in-process shared node)")
        .opt("shards", "",
             "domain-sharded shared nodes: addr[,addr...] or \
              domain=addr pins; repeat a domain across addresses to \
              replicate it (mutually exclusive with --remote)")
        .opt("probe-ms", "500",
             "min spacing between reconnect probes of a down shard")
        .opt("health-every", "8",
             "poll shard Health reports once per this many collects \
              (0 = never; transport errors still mark shards down)")
        .opt("domains", "",
             "request domain mix, round-robin (default: one domain)")
        .opt("expect-digest", "",
             "pin the remote store digest(s), hex, one per shard \
              (printed by every remote run; refuses a diverged store)")
        .opt("emit-tokens", "",
             "write greedy token streams to this JSON (bit-compare runs)")
        .opt("trace", "",
             "write a Chrome-trace span timeline here at exit (client \
              spans + echoed shared-node spans, one trace id)")
        .flag("synthetic",
              "synthetic weights + online-registered domains (no artifacts)")
        .parse_from(argv)?;
    moska::disagg::run_sim(&args)
}

fn cmd_shared_node(argv: &[String]) -> moska::Result<()> {
    let args = Cli::new("moska shared-node",
                        "standalone Shared KV node (plan execution over TCP)")
        .opt("addr", "127.0.0.1:7070", "listen address")
        .opt("artifacts", "", "artifacts dir (default: auto-discover)")
        .opt("threads", "0", "native exec threads (0 = auto, 1 = serial)")
        .opt("kernel", "auto",
             "kernel flavor: auto | simd | scalar | lanes8 | avx512 (MOSKA_KERNEL)")
        .opt("kv-dtype", "auto",
             "K/V storage dtype: auto | f32 | f16 | bf16 | int8 (MOSKA_KV_DTYPE)")
        .opt("domains", "",
             "serve only these domains (comma list) — one shard of a \
              domain-sharded deployment")
        .opt("drain-ms", "5000",
             "SIGTERM/SIGINT: max wait for in-flight plans before \
              force-closing connections (then exit 0)")
        .opt("trace", "",
             "write a Chrome-trace span timeline here on shutdown")
        .flag("synthetic",
              "serve the synthetic bench store (no artifacts)")
        .parse_from(argv)?;
    moska::remote::server::run_shared_node(&args)
}

fn cmd_replay(argv: &[String]) -> moska::Result<()> {
    let args = Cli::new("moska replay", "open-loop workload replay")
        .opt("artifacts", "", "artifacts dir (default: auto-discover)")
        .opt("requests", "24", "number of requests")
        .opt("rate", "8.0", "offered load (requests/sec)")
        .opt("top-k", "16", "router top-k (0 = dense)")
        .opt("backend", "xla", "xla | native")
        .opt("threads", "0", "native exec threads (0 = auto, 1 = serial)")
        .opt("kernel", "auto",
             "kernel flavor: auto | simd | scalar | lanes8 | avx512 (MOSKA_KERNEL)")
        .opt("kv-dtype", "auto",
             "K/V storage dtype: auto | f32 | f16 | bf16 | int8 (MOSKA_KV_DTYPE)")
        .opt("max-batch", "32", "max decode batch")
        .opt("trace", "", "replay a recorded trace file instead")
        .parse_from(argv)?;
    moska::engine::replay::run_replay(&args)
}

fn cmd_trace(argv: &[String]) -> moska::Result<()> {
    let args = Cli::new("moska trace", "record a workload trace to JSON")
        .opt("out", "trace.json", "output path")
        .opt("requests", "50", "number of requests")
        .opt("rate", "8.0", "offered load (requests/sec)")
        .opt("seed", "7", "generator seed")
        .opt("skew", "1.1", "domain Zipf skew")
        .parse_from(argv)?;
    let cfg = moska::workload::WorkloadConfig {
        rate: args.f64("rate")?,
        domain_skew: args.f64("skew")?,
        ..Default::default()
    };
    let mut gen = moska::workload::Generator::new(
        cfg, args.usize("seed")? as u64,
    );
    let items = gen.take(args.usize("requests")?);
    let out = args.str("out")?;
    std::fs::write(&out, moska::workload::trace_to_json(&items).to_string())?;
    println!("wrote {} requests to {out} (rate {:.1}/s, span {:.2}s)",
             items.len(), args.f64("rate")?,
             items.last().map(|i| i.arrival).unwrap_or(0.0));
    Ok(())
}

fn cmd_artifacts_info(argv: &[String]) -> moska::Result<()> {
    let args = Cli::new("moska artifacts-info", "manifest summary")
        .opt("artifacts", "", "artifacts dir (default: auto-discover)")
        .parse_from(argv)?;
    let dir = moska::runtime::artifact::resolve_artifacts_dir(&args);
    let man = moska::runtime::Manifest::load(&dir)?;
    println!("artifacts dir : {dir}");
    println!("model         : {:?}", man.model);
    println!("chunk tokens  : {}", man.chunk);
    println!("batch buckets : {:?}", man.batch_buckets);
    println!("router buckets: {:?}", man.router_chunk_buckets);
    println!("domains       :");
    for d in &man.domains {
        println!("  {:<10} {:>6} tokens  {:>4} chunks  ({})",
                 d.name, d.tokens, d.chunks, d.file);
    }
    println!("artifacts     : {}", man.artifact_count());
    for n in man.artifact_names() {
        println!("  {n}");
    }
    Ok(())
}
