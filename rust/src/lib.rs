//! # MoSKA — Mixture of Shared KV Attention
//!
//! Full-system reproduction of *"MoSKA: Mixture of Shared KV Attention for
//! Efficient Long-Sequence LLM Inference"* (Rhee et al., IEEE CAL 2025) as a
//! three-layer rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: MoE-inspired chunk
//!   routing ([`router`]), Shared-KV GEMM batch forming ([`batcher`]), paged
//!   unique KV cache + persistent shared chunk store ([`kvcache`]),
//!   SLO-aware scheduling ([`scheduler`]), the request engine ([`engine`]),
//!   a disaggregated two-node runtime ([`disagg`]), and the paper's
//!   analytical evaluation model ([`analytical`]).
//! * **L2/L1 (build time)** — `python/compile/` lowers the moska-tiny JAX
//!   graph and the Pallas Shared-KV attention kernel to HLO-text artifacts;
//!   [`runtime`] loads and executes them through the PJRT C API (`xla`
//!   crate). Python is never on the request path.
//!
//! Start at [`engine::Engine`] for the serving system or
//! [`analytical::figures`] for the paper's figures.

pub mod analytical;
pub mod attention;
pub mod batcher;
pub mod config;
pub mod disagg;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod plan;
pub mod remote;
pub mod router;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod tensor;
pub mod trace;
pub mod util;
pub mod workload;

/// Crate-wide result alias (anyhow is the only error dependency).
pub type Result<T> = anyhow::Result<T>;
