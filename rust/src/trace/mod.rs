//! Structured step tracing: lock-cheap per-thread span recorders that
//! export one Chrome-trace/Perfetto JSON timeline spanning the engine,
//! the fabric transport, and remote shared nodes.
//!
//! Design rules:
//!
//! * **Disabled is a no-op.** Every recording site first checks
//!   [`enabled`] — one relaxed atomic load and a predictable branch.
//!   The [`span!`][crate::span] macro does not even evaluate its
//!   argument expressions when tracing is off, so the decode hot path
//!   pays nothing (and tokens are bit-identical either way: tracing only
//!   reads clocks, never touches numerics).
//! * **Lock-cheap when enabled.** Each thread owns an
//!   `Arc<Mutex<Vec<Event>>>` registered once with the global
//!   collector; recording locks the thread's *own* uncontended mutex.
//!   The only cross-thread locking happens at export time.
//! * **One timeline across machines.** The client allocates a trace id
//!   ([`trace_id`]) and ships it (plus the emitting span's id) in the
//!   codec-v5 trace context on each `ExecShared` frame; shared nodes
//!   echo their exec span timings in the reply, stamped on their own
//!   monotonic clock. The handshake measures the clock offset
//!   (NTP-style midpoint, see `RemoteClient::handshake`), and
//!   [`record_remote`] maps the server timestamps onto the client
//!   timeline under a distinct Perfetto process id.
//!
//! Span taxonomy and the wire rules live in `docs/OBSERVABILITY.md`.

use std::borrow::Cow;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Perfetto process id used for spans recorded in this process.
pub const LOCAL_PID: u32 = 1;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static TRACE_ID: AtomicU64 = AtomicU64::new(0);
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);
static NEXT_PID: AtomicU32 = AtomicU32::new(LOCAL_PID + 1);

fn collector() -> &'static Mutex<Vec<Arc<Mutex<Vec<Event>>>>> {
    static C: OnceLock<Mutex<Vec<Arc<Mutex<Vec<Event>>>>>> = OnceLock::new();
    C.get_or_init(|| Mutex::new(Vec::new()))
}

fn process_labels() -> &'static Mutex<Vec<(u32, String)>> {
    static P: OnceLock<Mutex<Vec<(u32, String)>>> = OnceLock::new();
    P.get_or_init(|| {
        Mutex::new(vec![(LOCAL_PID, "moska".to_string())])
    })
}

thread_local! {
    static THREAD_BUF: RefCell<Option<(u32, Arc<Mutex<Vec<Event>>>)>> =
        const { RefCell::new(None) };
}

/// A span argument value (rendered into the Chrome-trace `args` object).
#[derive(Debug, Clone)]
pub enum Arg {
    U64(u64),
    I64(i64),
    F64(f64),
    Str(String),
}

impl From<u64> for Arg {
    fn from(v: u64) -> Arg { Arg::U64(v) }
}
impl From<u32> for Arg {
    fn from(v: u32) -> Arg { Arg::U64(v as u64) }
}
impl From<usize> for Arg {
    fn from(v: usize) -> Arg { Arg::U64(v as u64) }
}
impl From<i64> for Arg {
    fn from(v: i64) -> Arg { Arg::I64(v) }
}
impl From<i32> for Arg {
    fn from(v: i32) -> Arg { Arg::I64(v as i64) }
}
impl From<f64> for Arg {
    fn from(v: f64) -> Arg { Arg::F64(v) }
}
impl From<&str> for Arg {
    fn from(v: &str) -> Arg { Arg::Str(v.to_string()) }
}
impl From<String> for Arg {
    fn from(v: String) -> Arg { Arg::Str(v) }
}

impl Arg {
    fn to_json(&self) -> Json {
        match self {
            Arg::U64(v) => Json::num(*v as f64),
            Arg::I64(v) => Json::num(*v as f64),
            Arg::F64(v) => Json::num(*v),
            Arg::Str(s) => Json::str(s.clone()),
        }
    }
}

/// One completed span (Chrome-trace "X" duration event).
#[derive(Debug, Clone)]
struct Event {
    name: Cow<'static, str>,
    cat: &'static str,
    /// Client-timeline start, ns since the trace epoch (remote spans are
    /// offset-corrected before recording, so this can be negative only
    /// for pathological clock skew).
    start_ns: i64,
    dur_ns: u64,
    pid: u32,
    tid: u32,
    /// Span id (unique within the trace; 0 for remote spans whose
    /// parent linkage travels through args instead).
    id: u64,
    args: Vec<(&'static str, Arg)>,
}

/// Whether tracing is recording. One relaxed load — callers branch on
/// this before building any span arguments.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on: anchor the epoch and allocate a nonzero trace id
/// for this process (idempotent; the first call wins).
pub fn enable() {
    let _ = EPOCH.get_or_init(Instant::now);
    if TRACE_ID.load(Ordering::Relaxed) == 0 {
        // unique enough across processes without wall-clock access:
        // pid in the high bits, an ASLR-derived stamp below
        let aslr = (&ENABLED as *const AtomicBool as usize as u64)
            & 0xFFFF_FFFF;
        let id = ((std::process::id() as u64) << 32) | aslr | 1;
        let _ = TRACE_ID.compare_exchange(0, id, Ordering::Relaxed,
                                          Ordering::Relaxed);
    }
    ENABLED.store(true, Ordering::Relaxed);
}

/// Nanoseconds since the trace epoch (monotonic). Works whether or not
/// recording is enabled — remote servers use it to stamp echoed spans.
#[inline]
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// This process's trace id (0 until [`enable`] ran).
pub fn trace_id() -> u64 {
    TRACE_ID.load(Ordering::Relaxed)
}

/// Allocate a fresh span id.
pub fn next_span_id() -> u64 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

/// Register a remote process row in the exported timeline (one per
/// shared node); returns its Perfetto pid.
pub fn register_remote_process(label: &str) -> u32 {
    let pid = NEXT_PID.fetch_add(1, Ordering::Relaxed);
    process_labels().lock().unwrap().push((pid, label.to_string()));
    pid
}

fn with_thread_buf(f: impl FnOnce(u32, &mut Vec<Event>)) {
    THREAD_BUF.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let buf = Arc::new(Mutex::new(Vec::new()));
            collector().lock().unwrap().push(buf.clone());
            *slot = Some((tid, buf));
        }
        let (tid, buf) = slot.as_ref().unwrap();
        f(*tid, &mut buf.lock().unwrap());
    });
}

/// RAII scoped span. Build through the [`span!`][crate::span] macro (or
/// [`SpanGuard::start`]); the span records on drop.
pub struct SpanGuard(Option<SpanInner>);

struct SpanInner {
    name: Cow<'static, str>,
    cat: &'static str,
    start_ns: u64,
    id: u64,
    args: Vec<(&'static str, Arg)>,
}

impl SpanGuard {
    /// Start a recording span (caller checked [`enabled`]).
    pub fn start(name: impl Into<Cow<'static, str>>, cat: &'static str,
                 args: Vec<(&'static str, Arg)>) -> SpanGuard {
        SpanGuard(Some(SpanInner {
            name: name.into(),
            cat,
            start_ns: now_ns(),
            id: next_span_id(),
            args,
        }))
    }

    /// A guard that records nothing (tracing disabled).
    pub const fn inert() -> SpanGuard {
        SpanGuard(None)
    }

    /// This span's id (0 when inert) — the value shipped as the wire
    /// trace context's parent span id.
    pub fn id(&self) -> u64 {
        self.0.as_ref().map(|s| s.id).unwrap_or(0)
    }

    /// Append an argument discovered mid-span (no-op when inert).
    pub fn arg(&mut self, k: &'static str, v: impl Into<Arg>) {
        if let Some(s) = self.0.as_mut() {
            s.args.push((k, v.into()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(s) = self.0.take() else { return };
        let dur = now_ns().saturating_sub(s.start_ns);
        with_thread_buf(|tid, buf| {
            buf.push(Event {
                name: s.name,
                cat: s.cat,
                start_ns: s.start_ns as i64,
                dur_ns: dur,
                pid: LOCAL_PID,
                tid,
                id: s.id,
                args: s.args,
            });
        });
    }
}

/// Scoped span: `let _g = crate::span!("decode.step", "engine");` or with
/// args `crate::span!("layer", "exec", "layer" => l, "rows" => b)`.
/// Argument expressions are not evaluated when tracing is disabled.
#[macro_export]
macro_rules! span {
    ($name:expr, $cat:expr $(, $k:literal => $v:expr)* $(,)?) => {
        if $crate::trace::enabled() {
            $crate::trace::SpanGuard::start(
                $name, $cat,
                vec![$(($k, $crate::trace::Arg::from($v))),*],
            )
        } else {
            $crate::trace::SpanGuard::inert()
        }
    };
}

/// Record a completed span with explicit timing (used where a guard
/// cannot scope the region, e.g. the engine's phase timers).
pub fn record(name: impl Into<Cow<'static, str>>, cat: &'static str,
              start_ns: u64, dur_ns: u64,
              args: Vec<(&'static str, Arg)>) {
    if !enabled() {
        return;
    }
    with_thread_buf(|tid, buf| {
        buf.push(Event {
            name: name.into(),
            cat,
            start_ns: start_ns as i64,
            dur_ns,
            pid: LOCAL_PID,
            tid,
            id: next_span_id(),
            args,
        });
    });
}

/// Record a span echoed by a remote shared node, already mapped onto
/// the client timeline (`start_client_ns = server_ns - clock_offset`).
/// `pid` comes from [`register_remote_process`]; `args` should carry the
/// wire trace context (`trace_id`, `parent`) so exported remote spans
/// are attributable to the client's trace.
pub fn record_remote(pid: u32, name: String, start_client_ns: i64,
                     dur_ns: u64, args: Vec<(&'static str, Arg)>) {
    if !enabled() {
        return;
    }
    with_thread_buf(|_, buf| {
        buf.push(Event {
            name: Cow::Owned(name),
            cat: "remote",
            start_ns: start_client_ns,
            dur_ns,
            pid,
            // remote spans render on one row per remote process
            tid: 1,
            id: 0,
            args,
        });
    });
}

/// Hex rendering of a trace id as it travels through span args and
/// exported JSON (`0x…`).
pub fn fmt_trace_id(id: u64) -> String {
    format!("{id:#018x}")
}

/// Number of events recorded so far (test support).
pub fn event_count() -> usize {
    collector()
        .lock()
        .unwrap()
        .iter()
        .map(|b| b.lock().unwrap().len())
        .sum()
}

/// Drop every recorded event (test isolation).
pub fn clear() {
    for buf in collector().lock().unwrap().iter() {
        buf.lock().unwrap().clear();
    }
}

/// Snapshot all recorded spans as Chrome-trace JSON
/// (`{"traceEvents": [...]}`; load in Perfetto / `chrome://tracing`).
/// Buffers are not drained, so periodic exports overwrite the file with
/// a strictly longer timeline.
pub fn export_json_string() -> String {
    let mut events: Vec<Json> = Vec::new();
    for (pid, label) in process_labels().lock().unwrap().iter() {
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_name")),
            ("pid", Json::num(*pid as f64)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(label.clone()))])),
        ]));
    }
    let snapshot: Vec<Event> = {
        let bufs = collector().lock().unwrap();
        bufs.iter()
            .flat_map(|b| b.lock().unwrap().clone())
            .collect()
    };
    for e in snapshot {
        let mut args: Vec<(&str, Json)> = e
            .args
            .iter()
            .map(|(k, v)| (*k, v.to_json()))
            .collect();
        if e.id != 0 {
            args.push(("span_id", Json::num(e.id as f64)));
        }
        events.push(Json::obj(vec![
            ("ph", Json::str("X")),
            ("name", Json::str(e.name.into_owned())),
            ("cat", Json::str(e.cat)),
            ("ts", Json::num(e.start_ns as f64 / 1000.0)),
            ("dur", Json::num(e.dur_ns as f64 / 1000.0)),
            ("pid", Json::num(e.pid as f64)),
            ("tid", Json::num(e.tid as f64)),
            ("args", Json::obj(args)),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        ("otherData", Json::obj(vec![
            ("trace_id", Json::str(fmt_trace_id(trace_id()))),
        ])),
    ])
    .to_string()
}

/// Write the Chrome-trace JSON to `path` (atomic: temp file + rename).
pub fn export_json(path: &str) -> Result<()> {
    let body = export_json_string();
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, body.as_bytes())
        .with_context(|| format!("writing trace {tmp}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming trace into {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        // tracing starts disabled in this process unless another test
        // enabled it; either way an inert guard must not record
        let before = event_count();
        {
            let _g = SpanGuard::inert();
        }
        assert_eq!(event_count(), before);
    }

    #[test]
    fn span_guard_records_on_drop_when_enabled() {
        enable();
        let before = event_count();
        {
            let mut g = SpanGuard::start("test.span", "test",
                                         vec![("k", Arg::from(7u64))]);
            g.arg("later", 1u64);
            assert!(g.id() > 0);
        }
        assert_eq!(event_count(), before + 1);
        let body = export_json_string();
        let j = Json::parse(&body).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        let span = evs
            .iter()
            .find(|e| {
                e.opt("name").map(|n| n.as_str().unwrap_or(""))
                    == Some("test.span")
            })
            .expect("exported span present");
        assert!(span.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        let args = span.get("args").unwrap();
        assert_eq!(args.get("k").unwrap().as_usize().unwrap(), 7);
        assert_eq!(args.get("later").unwrap().as_usize().unwrap(), 1);
        assert!(args.get("span_id").unwrap().as_usize().unwrap() > 0);
        assert!(j.get("otherData").unwrap().get("trace_id").is_ok());
        assert!(trace_id() != 0);
    }

    #[test]
    fn remote_spans_land_under_their_pid() {
        enable();
        let pid = register_remote_process("shared-node test");
        record_remote(pid, "node.exec".into(), 1234, 567,
                      vec![("trace_id", Arg::Str(fmt_trace_id(trace_id())))]);
        let body = export_json_string();
        let j = Json::parse(&body).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs.iter().any(|e| {
            let pid_ok = e.opt("pid").and_then(|p| p.as_usize().ok())
                == Some(pid as usize);
            let cat_ok = e.opt("cat").and_then(|c| c.as_str().ok())
                == Some("remote");
            pid_ok && cat_ok
        }));
    }
}
