//! Disaggregated-simulation + HTTP-server integration tests.
//!
//! Validates the paper's §III.C behaviour on the live tiny system: shared
//! node traffic flat in batch (dense routing), unique node traffic linear,
//! GEMM batching factor = B; plus a full HTTP round trip through the
//! serving endpoint.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use moska::config::ServingConfig;
use moska::disagg::DisaggCluster;
use moska::kvcache::shared_store::SharedStore;
use moska::model::Weights;
use moska::runtime::{artifact::default_artifacts_dir, Backend, Manifest,
                     NativeBackend};
use moska::util::json::Json;

fn artifacts_dir() -> Option<String> {
    let dir = default_artifacts_dir();
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn native_cluster(dir: &str, top_k: Option<usize>) -> DisaggCluster {
    let man = Manifest::load(dir).unwrap();
    let weights = Weights::load(
        man.weights_path().to_str().unwrap(), man.model.clone(),
    )
    .unwrap();
    let shared = Arc::new(SharedStore::load_from_manifest(&man).unwrap());
    let backend: Arc<dyn Backend> =
        Arc::new(NativeBackend::new(man.model.clone(), man.chunk));
    DisaggCluster::new(backend, weights, shared, top_k, 32)
}

#[test]
fn shared_node_traffic_flat_in_batch_when_dense() {
    let Some(dir) = artifacts_dir() else { return };
    // dense routing: every query reads every chunk, but the batcher
    // collapses the reads → shared bytes/step must NOT grow with batch.
    let mut c1 = native_cluster(&dir, None);
    let p1 = c1.run_point(1, "code", 32, 3).unwrap();
    let mut c8 = native_cluster(&dir, None);
    let p8 = c8.run_point(8, "code", 32, 3).unwrap();

    assert!(
        (p8.shared_bytes_per_step - p1.shared_bytes_per_step).abs()
            < 0.01 * p1.shared_bytes_per_step.max(1.0),
        "shared reads grew with batch: {} vs {}",
        p8.shared_bytes_per_step, p1.shared_bytes_per_step
    );
    // unique traffic grows ~linearly (8 requests × their own pages); the
    // weight stream is a per-step constant, so compare KV reads only
    let man = Manifest::load(&dir).unwrap();
    let wb = Weights::load(man.weights_path().to_str().unwrap(),
                           man.model.clone())
        .unwrap()
        .param_count() as f64 * 4.0;
    let uniq1 = p1.unique_bytes_per_step - wb;
    let uniq8 = p8.unique_bytes_per_step - wb;
    assert!(
        uniq8 > 5.0 * uniq1,
        "unique KV reads not scaling: {uniq8} vs {uniq1}"
    );
    // GEMM batching factor == batch under identical routing
    assert!((p8.batching_factor - 8.0).abs() < 1e-6,
            "batching factor {}", p8.batching_factor);
    assert!((p1.batching_factor - 1.0).abs() < 1e-6);
    // shared flops grow with batch (more GEMM rows, same bytes) — the
    // arithmetic-intensity shift that defines Shared KV Attention
    assert!(p8.shared_flops_per_step > 5.0 * p1.shared_flops_per_step);
}

#[test]
fn sparse_routing_reduces_shared_flops() {
    let Some(dir) = artifacts_dir() else { return };
    let mut dense = native_cluster(&dir, None);
    let pd = dense.run_point(4, "legal", 32, 3).unwrap();
    let mut sparse = native_cluster(&dir, Some(4)); // 4 of 64 chunks
    let ps = sparse.run_point(4, "legal", 32, 3).unwrap();
    assert!(
        ps.shared_flops_per_step < 0.25 * pd.shared_flops_per_step,
        "sparse {} vs dense {}",
        ps.shared_flops_per_step, pd.shared_flops_per_step
    );
    assert!(ps.shared_bytes_per_step < 0.25 * pd.shared_bytes_per_step);
}

#[test]
fn disagg_decode_matches_engine_tokens() {
    // The split execution must produce the same greedy tokens as the
    // monolithic engine for a request with the same state. We cross-check
    // via golden-style decode: seed a disagg request whose unique KV was
    // built by the engine prefill... simplest equivalent: both run decode
    // from identical synthetic state via the same seed.
    let Some(dir) = artifacts_dir() else { return };
    let mut a = native_cluster(&dir, None);
    let mut reqs_a = a.seed_requests(3, "code", 16, 99).unwrap();
    let mut b = native_cluster(&dir, None);
    let mut reqs_b = b.seed_requests(3, "code", 16, 99).unwrap();
    for _ in 0..4 {
        a.step(&mut reqs_a).unwrap();
        b.step(&mut reqs_b).unwrap();
    }
    for (ra, rb) in reqs_a.iter().zip(&reqs_b) {
        assert_eq!(ra.cur, rb.cur, "disagg decode non-deterministic");
        assert_eq!(ra.pos, rb.pos);
    }
}

#[test]
fn http_server_round_trip() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServingConfig { top_k: Some(4), ..Default::default() };
    let (engine, _svc) =
        moska::engine::build_engine(&dir, "native", cfg).unwrap();
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = moska::server::serve_on(
            "127.0.0.1:0".parse().unwrap(), engine, Some(ready_tx),
        );
    });
    let addr = ready_rx.recv().unwrap();

    // POST /generate
    let body = r#"{"prompt": "what is clause 7?", "domain": "legal",
                   "max_tokens": 5}"#;
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(), body
    )
    .unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let json_body = resp.split("\r\n\r\n").nth(1).unwrap();
    let j = Json::parse(json_body).unwrap();
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 5);
    assert!(j.get("decode_secs").unwrap().as_f64().unwrap() >= 0.0);

    // GET /stats
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET /stats HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let j = Json::parse(resp.split("\r\n\r\n").nth(1).unwrap()).unwrap();
    assert!(j.get("engine").is_ok());

    // GET /healthz
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert!(resp.ends_with("ok"));

    // bad request rejected cleanly
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "POST /generate HTTP/1.1\r\nContent-Length: 2\r\n\r\n{{}}")
        .unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
}
