//! Kernel-flavor property tests: the SIMD microkernel determinism
//! contract (see `runtime/simd.rs`).
//!
//! 1. The portable `lanes8` flavor and the best runtime-detected flavor
//!    (AVX2/NEON where available) are **bit-identical** on every routed
//!    kernel, across randomized shapes with ragged tails and across
//!    serial/pooled execution.
//! 2. The `scalar` flavor reproduces the **seed kernels'** arithmetic
//!    bit-for-bit (inline naive references, plus real K/V taken from
//!    the synthetic store).
//! 3. End to end: decode **tokens** are identical across
//!    `scalar`/`simd`/`lanes8` backends and across thread counts, on
//!    both the engine and the disagg cluster.

use std::sync::Arc;

use moska::config::{ModelConfig, ServingConfig};
use moska::disagg::{synthetic_store, synthetic_weights, DisaggCluster,
                    SYNTH_CHUNK, SYNTH_DOMAIN, SYNTH_DOMAIN_B};
use moska::engine::Engine;
use moska::kvcache::SharedStore;
use moska::model::sampling::Sampler;
use moska::model::Weights;
use moska::runtime::native::{self, Partials};
use moska::runtime::{kernels_for, Backend, KernelSpec, Kernels,
                     NativeBackend};
use moska::tensor::{KvDtype, Tensor};
use moska::util::rng::Rng;
use moska::util::threadpool::ThreadPool;

fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let mut d = vec![0f32; shape.iter().product()];
    rng.fill_normal_f32(&mut d);
    Tensor::f32(shape, d)
}

/// Flavor A == flavor B, bit for bit, on every routed kernel, for
/// randomized shapes whose dims are deliberately NOT multiples of the
/// 8-lane width, serial and pooled.
#[test]
fn simd_flavors_bit_identical_across_shapes() {
    let a = kernels_for(KernelSpec::Lanes8);
    let b = kernels_for(KernelSpec::Simd);
    let mut rng = Rng::new(0xFA57);
    let pool = ThreadPool::new(3);
    for round in 0..6 {
        // ragged on purpose: d, n, dh, c, valid hit every residue mod 8
        let bsz = 1 + rng.below(5) as usize;
        let d = 33 + rng.below(77) as usize;
        let n = 47 + rng.below(130) as usize;
        let x = rand_t(&mut rng, &[bsz, d]);
        let w = rand_t(&mut rng, &[d, n]);
        for pool_opt in [None, Some(&pool)] {
            let ma = native::matmul_exec_kern(&x, &w, pool_opt, a);
            let mb = native::matmul_exec_kern(&x, &w, pool_opt, b);
            assert_eq!(ma, mb, "matmul round {round} b={bsz} d={d} n={n}");
        }

        let hkv = 1 + rng.below(2) as usize;
        let h = hkv * (1 + rng.below(3) as usize);
        let dh = 9 + rng.below(40) as usize;
        let c = 17 + rng.below(90) as usize;
        let q = rand_t(&mut rng, &[bsz, h, dh]);
        let k = rand_t(&mut rng, &[c, hkv, dh]);
        let v = rand_t(&mut rng, &[c, hkv, dh]);
        let mut q_pos: Vec<i32> =
            (0..bsz).map(|_| rng.below(2 * c as u64) as i32 - 3).collect();
        if bsz > 1 {
            q_pos[0] = -1; // padding row stays identity
        }
        let valid = 1 + rng.below(c as u64) as i32;
        for pool_opt in [None, Some(&pool)] {
            let pa = native::chunk_attn_exec_kern(&q, &k, &v, &q_pos, 2,
                                                  valid, pool_opt, a);
            let pb = native::chunk_attn_exec_kern(&q, &k, &v, &q_pos, 2,
                                                  valid, pool_opt, b);
            assert_eq!(pa.o, pb.o, "attn o round {round} dh={dh} c={c}");
            assert_eq!(pa.m, pb.m, "attn m round {round}");
            assert_eq!(pa.l, pb.l, "attn l round {round}");

            let embs = rand_t(&mut rng, &[c, hkv, dh]);
            assert_eq!(
                native::router_score_exec_kern(&q, &embs, pool_opt, a),
                native::router_score_exec_kern(&q, &embs, pool_opt, b),
                "router round {round}"
            );
        }

        // merge + finalize tails
        let p1 = native::chunk_attn_exec_kern(&q, &k, &v, &q_pos, 0,
                                              c as i32, None, a);
        let p2 = native::chunk_attn_exec_kern(&q, &k, &v, &q_pos, 7,
                                              valid, None, a);
        let merge = |kern: &'static Kernels| -> Partials {
            let mut acc = p1.clone();
            for row in 0..bsz {
                native::merge2_row_into_kern(kern, &mut acc, row, &p2, row);
            }
            acc
        };
        let (ga, gb) = (merge(a), merge(b));
        assert_eq!(ga.o, gb.o, "merge round {round}");
        assert_eq!(ga.l, gb.l, "merge l round {round}");
        let mut fa = vec![0f32; bsz * h * dh];
        let mut fb = vec![0f32; bsz * h * dh];
        native::finalize_into_kern(a, &ga, &mut fa);
        native::finalize_into_kern(b, &gb, &mut fb);
        assert_eq!(fa, fb, "finalize round {round}");
    }
}

/// The `scalar` flavor preserves the seed kernels bit-for-bit: compare
/// against naive inline references that replicate the seed arithmetic
/// (multiply-then-add, sequential `k`-ascending reductions).
#[test]
fn scalar_flavor_reproduces_seed_kernels() {
    let kern = kernels_for(KernelSpec::Scalar);
    let mut rng = Rng::new(0x5EED2);

    // matmul: plain (i, k, j) triple loop == seed tiled order
    let (b, d, n) = (3usize, 70usize, 101usize);
    let x = rand_t(&mut rng, &[b, d]);
    let w = rand_t(&mut rng, &[d, n]);
    let got = native::matmul_exec_kern(&x, &w, None, kern);
    let (xs, ws) = (x.as_f32(), w.as_f32());
    let mut want = vec![0f32; b * n];
    for i in 0..b {
        for k in 0..d {
            let xv = xs[i * d + k];
            for j in 0..n {
                want[i * n + j] += xv * ws[k * n + j];
            }
        }
    }
    assert_eq!(got.as_f32(), &want[..], "seed matmul arithmetic");

    // chunk attention over REAL K/V from the synthetic store
    let store = synthetic_store().expect("synthetic store");
    let dom = store.domain(SYNTH_DOMAIN).expect("domain");
    let (kc, vc) = dom.chunk_kv(0, 1);
    let (c, hkv, dh) = (kc.shape()[0], kc.shape()[1], kc.shape()[2]);
    let h = hkv * 2;
    let q = rand_t(&mut rng, &[2, h, dh]);
    let q_pos = [(2 * c) as i32, (c + 3) as i32];
    let k_base = c as i32; // chunk 1 sits at base c
    let got = native::chunk_attn_exec_kern(&q, kc, vc, &q_pos, k_base,
                                           c as i32, None, kern);
    // inline seed reference
    let group = h / hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let (qs, ks, vs) = (q.as_f32(), kc.as_f32(), vc.as_f32());
    let mut wo = vec![0f32; 2 * h * dh];
    let mut wm = vec![f32::NEG_INFINITY; 2 * h];
    let mut wl = vec![0f32; 2 * h];
    for r in 0..2 * h {
        let (bi, hi) = (r / h, r % h);
        let vis = ((q_pos[bi] - k_base + 1).clamp(0, c as i32)) as usize;
        if vis == 0 {
            continue;
        }
        let kv = hi / group;
        let qrow = &qs[(bi * h + hi) * dh..(bi * h + hi + 1) * dh];
        let mut scores = vec![0f32; vis];
        let mut mx = f32::NEG_INFINITY;
        for (j, slot) in scores.iter_mut().enumerate() {
            let krow = &ks[(j * hkv + kv) * dh..(j * hkv + kv + 1) * dh];
            let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
            *slot = dot * scale;
            mx = mx.max(*slot);
        }
        let mut li = 0f32;
        for (j, &s) in scores.iter().enumerate() {
            let p = (s - mx).exp();
            li += p;
            let vrow = &vs[(j * hkv + kv) * dh..(j * hkv + kv + 1) * dh];
            for (o, &vv) in
                wo[r * dh..(r + 1) * dh].iter_mut().zip(vrow)
            {
                *o += p * vv;
            }
        }
        wm[r] = mx;
        wl[r] = li;
    }
    assert_eq!(got.o.as_f32(), &wo[..], "seed attn o");
    assert_eq!(got.m.as_f32(), &wm[..], "seed attn m");
    assert_eq!(got.l.as_f32(), &wl[..], "seed attn l");

    // router scores against the store's layer-0 embeddings
    let embs = dom.embeddings(0);
    let got = native::router_score_exec_kern(&q, embs, None, kern);
    let (cc, ehkv) = (embs.shape()[0], embs.shape()[1]);
    let es = embs.as_f32();
    let egroup = h / ehkv;
    for bi in 0..2 {
        for ci in 0..cc {
            let mut acc = 0f32;
            for hi in 0..h {
                let kv = hi / egroup;
                let qrow = &qs[(bi * h + hi) * dh..(bi * h + hi + 1) * dh];
                let erow =
                    &es[(ci * ehkv + kv) * dh..(ci * ehkv + kv + 1) * dh];
                acc +=
                    qrow.iter().zip(erow).map(|(a, b)| a * b).sum::<f32>();
            }
            assert_eq!(got.as_f32()[bi * cc + ci], acc / h as f32,
                       "seed router cell ({bi},{ci})");
        }
    }
}

/// The synthetic store is built on the pinned scalar flavor regardless
/// of the ambient kernel selection: two builds in this process (whose
/// global flavor may be anything — CI sets MOSKA_KERNEL) are
/// bit-identical, which is what lets remote deployments mix per-node
/// flavors without tripping the digest handshake.
#[test]
fn synthetic_store_flavor_independent() {
    let s1 = synthetic_store().expect("store 1");
    let s2 = synthetic_store().expect("store 2");
    assert_eq!(s1.content_digest(), s2.content_digest());
}

fn flavor_engine(spec: KernelSpec, threads: usize) -> Engine {
    let model = ModelConfig::tiny();
    let cfg = ServingConfig {
        top_k: Some(4),
        max_batch: 16,
        exec_threads: threads,
        kernel: spec,
        ..Default::default()
    };
    let be = NativeBackend::with_threads(model.clone(), SYNTH_CHUNK,
                                         threads)
        .with_kernel_spec(spec);
    let mut eng = Engine::new(
        Box::new(be),
        Weights::synthetic(model, 0xF1A404),
        SharedStore::empty(SYNTH_CHUNK),
        cfg,
        1024,
    );
    let tokens: Vec<i32> =
        (0..4 * SYNTH_CHUNK).map(|i| (i % 251) as i32).collect();
    eng.register_domain("dom", &tokens).expect("register");
    eng
}

fn decode_tokens(spec: KernelSpec, threads: usize) -> Vec<Vec<i32>> {
    let mut eng = flavor_engine(spec, threads);
    for i in 0..4 {
        let p: Vec<i32> =
            (0..8).map(|j| ((i * 31 + j * 7) % 256) as i32).collect();
        eng.submit(Some("dom"), p, 6, Sampler::Greedy).unwrap();
    }
    let mut results = eng.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    results.into_iter().map(|r| r.tokens).collect()
}

/// Acceptance surface: decode tokens are identical across kernel
/// flavors and across thread counts (engine path, routed top-k).
#[test]
fn engine_tokens_identical_across_flavors_and_threads() {
    let base = decode_tokens(KernelSpec::Scalar, 1);
    assert_eq!(base, decode_tokens(KernelSpec::Simd, 1),
               "scalar vs simd tokens");
    assert_eq!(base, decode_tokens(KernelSpec::Lanes8, 1),
               "scalar vs lanes8 tokens");
    assert_eq!(base, decode_tokens(KernelSpec::Simd, 3),
               "simd serial vs pooled tokens");
}

/// Pack→widen roundtrip error is bounded per storage dtype, across
/// ragged shapes. f32 packing is the identity (bit-for-bit); f16/bf16
/// obey round-to-nearest-even half-ulp bounds; int8 stays within half
/// its per-token-row scale.
#[test]
fn pack_widen_roundtrip_bounded_per_dtype() {
    let mut rng = Rng::new(0xBAC0);
    for round in 0..8 {
        let rows = 1 + rng.below(40) as usize;
        let hkv = 1 + rng.below(3) as usize;
        let dh = 5 + rng.below(40) as usize;
        let t = rand_t(&mut rng, &[rows, hkv, dh]);
        let xs = t.as_f32().to_vec();

        let p32 = t.pack_kv(KvDtype::F32);
        assert!(!p32.is_packed());
        assert_eq!(p32.widen_to_f32().as_f32(), &xs[..],
                   "f32 pack round {round} is not the identity");

        // RNE conversions: |err| <= half-ulp (relative) + a tiny
        // absolute term for the f16 subnormal range
        for (dt, rel, abs) in [(KvDtype::F16, 4.883e-4f32, 1e-7f32),
                               (KvDtype::Bf16, 2.5e-3, 1e-30)] {
            let w = t.pack_kv(dt).widen_to_f32();
            for (i, (&a, &b)) in
                xs.iter().zip(w.as_f32()).enumerate()
            {
                assert!((a - b).abs() <= a.abs() * rel + abs,
                        "{dt} round {round} elem {i}: {a} -> {b}");
            }
        }

        // int8: q = round(x * 127/rowmax), widened as q * rowmax/127
        let w = t.pack_kv(KvDtype::I8).widen_to_f32();
        let ws = w.as_f32();
        let row = hkv * dh;
        for r in 0..rows {
            let rmax = xs[r * row..(r + 1) * row]
                .iter()
                .fold(0f32, |m, &v| m.max(v.abs()));
            let bound = 0.51 * rmax / 127.0;
            for j in 0..row {
                let (a, b) = (xs[r * row + j], ws[r * row + j]);
                assert!((a - b).abs() <= bound,
                        "int8 round {round} row {r} elem {j}: \
                         {a} -> {b} (rowmax {rmax})");
            }
        }
    }
}

/// Packed-K/V chunk attention is bit-identical across every kernel
/// flavor (the vectorized widen paths must reproduce the scalar
/// widening oracle exactly), on ragged shapes, serial and pooled.
#[test]
fn packed_widening_bit_identical_across_flavors() {
    let scalar = kernels_for(KernelSpec::Scalar);
    let lanes8 = kernels_for(KernelSpec::Lanes8);
    let simd = kernels_for(KernelSpec::Simd);
    let mut rng = Rng::new(0xFACC2);
    let pool = ThreadPool::new(3);
    for round in 0..4 {
        let bsz = 1 + rng.below(4) as usize;
        let hkv = 1 + rng.below(2) as usize;
        let h = hkv * (1 + rng.below(3) as usize);
        let dh = 9 + rng.below(40) as usize;
        let c = 17 + rng.below(90) as usize;
        let q = rand_t(&mut rng, &[bsz, h, dh]);
        let kf = rand_t(&mut rng, &[c, hkv, dh]);
        let vf = rand_t(&mut rng, &[c, hkv, dh]);
        let q_pos: Vec<i32> =
            (0..bsz).map(|_| rng.below(2 * c as u64) as i32 - 3).collect();
        let valid = 1 + rng.below(c as u64) as i32;
        for dt in [KvDtype::F16, KvDtype::Bf16, KvDtype::I8] {
            let k = kf.pack_kv(dt);
            let v = vf.pack_kv(dt);
            for pool_opt in [None, Some(&pool)] {
                let ps = native::chunk_attn_exec_kern(
                    &q, &k, &v, &q_pos, 2, valid, pool_opt, scalar,
                );
                for flavor in [lanes8, simd] {
                    let pf = native::chunk_attn_exec_kern(
                        &q, &k, &v, &q_pos, 2, valid, pool_opt, flavor,
                    );
                    assert_eq!(ps.o, pf.o,
                               "{dt} o round {round} [{}]", flavor.name);
                    assert_eq!(ps.m, pf.m, "{dt} m round {round}");
                    assert_eq!(ps.l, pf.l, "{dt} l round {round}");
                }
            }
        }
    }
}

/// Store digests are a pure function of (content, storage dtype):
/// stable across rebuilds, unchanged by f32 packing (wire compat with
/// pre-dtype deployments), and distinct per packed dtype — the digest
/// handshake must catch mixed-dtype deployments.
#[test]
fn store_digest_stable_per_dtype() {
    let base = synthetic_store().expect("store");
    for dt in [KvDtype::F32, KvDtype::F16, KvDtype::Bf16, KvDtype::I8] {
        let pack = |_: usize| {
            let mut s = synthetic_store().expect("store");
            s.pack_to(dt);
            s
        };
        let (a, b) = (pack(0), pack(1));
        assert_eq!(a.content_digest(), b.content_digest(),
                   "{dt} digest not stable across rebuilds");
        assert_eq!(a.kv_dtype, dt);
        if dt == KvDtype::F32 {
            assert_eq!(a.content_digest(), base.content_digest(),
                       "f32 packing must not perturb the seed digest");
        } else {
            assert_ne!(a.content_digest(), base.content_digest(),
                       "{dt} digest must differ from the f32 digest");
        }
        assert!(a.resident_bytes() <= base.resident_bytes(),
                "{dt} packing grew the store");
    }
}

/// Same property on the disagg cluster (both nodes on one flavor),
/// over the scalar-pinned synthetic store.
#[test]
fn disagg_tokens_identical_across_flavors() {
    let domains =
        vec![SYNTH_DOMAIN.to_string(), SYNTH_DOMAIN_B.to_string()];
    let run = |spec: KernelSpec| {
        let store = Arc::new(synthetic_store().expect("store"));
        let mk = || -> Arc<dyn Backend> {
            Arc::new(
                NativeBackend::with_threads(ModelConfig::tiny(),
                                            SYNTH_CHUNK, 1)
                    .with_kernel_spec(spec),
            )
        };
        let mut cluster = DisaggCluster::with_backends(
            mk(), mk(), synthetic_weights(), store, Some(4), 32,
        );
        cluster.run_point_mixed(4, &domains, 16, 6).expect("run").tokens
    };
    let scalar = run(KernelSpec::Scalar);
    assert_eq!(scalar, run(KernelSpec::Simd), "disagg scalar vs simd");
    assert_eq!(scalar, run(KernelSpec::Lanes8),
               "disagg scalar vs lanes8");
}
