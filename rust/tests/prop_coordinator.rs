//! Property tests over coordinator invariants (in-crate proptest-lite,
//! `moska::util::prop`): batch forming, routing, paging, LSE algebra,
//! JSON round-trips. Pure rust — no artifacts needed.

use moska::batcher::form_batches;
use moska::config::ModelConfig;
use moska::kvcache::paged::{PagePool, RequestKv};
use moska::prop_assert;
use moska::router::top_k_indices;
use moska::runtime::native;
use moska::runtime::{Backend, NativeBackend};
use moska::tensor::Tensor;
use moska::util::prop::{check, Case, Config};
use moska::util::rng::Rng;

// ---------------------------------------------------------------- cases

#[derive(Debug, Clone)]
struct RoutingCase {
    sets: Vec<Vec<usize>>,
    max_batch: usize,
}

impl Case for RoutingCase {
    fn shrink(&self) -> Vec<RoutingCase> {
        let mut out = Vec::new();
        if self.sets.len() > 1 {
            out.push(RoutingCase {
                sets: self.sets[..self.sets.len() / 2].to_vec(),
                max_batch: self.max_batch,
            });
        }
        if self.sets.iter().any(|s| s.len() > 1) {
            out.push(RoutingCase {
                sets: self
                    .sets
                    .iter()
                    .map(|s| s[..s.len() / 2].to_vec())
                    .collect(),
                max_batch: self.max_batch,
            });
        }
        out
    }
}

fn gen_routing(rng: &mut Rng) -> RoutingCase {
    let b = rng.range(1, 40);
    let n_chunks = rng.range(1, 64);
    let sets = (0..b)
        .map(|_| {
            let k = rng.range(0, n_chunks.min(12) + 1);
            let mut set: Vec<usize> =
                (0..k).map(|_| rng.range(0, n_chunks)).collect();
            set.sort_unstable();
            set.dedup();
            set
        })
        .collect();
    RoutingCase { sets, max_batch: rng.range(1, 33) }
}

#[test]
fn prop_batcher_conservation_and_bounds() {
    check("batcher-conservation", Config::default(), gen_routing, |case| {
        let (batches, stats) = form_batches(&case.sets, case.max_batch);
        // bucket bound
        for b in &batches {
            prop_assert!(b.rows.len() <= case.max_batch,
                         "batch over bound: {} > {}", b.rows.len(),
                         case.max_batch);
        }
        // conservation: every (row, chunk) pair appears exactly once
        let mut seen = std::collections::HashSet::new();
        for b in &batches {
            for &r in &b.rows {
                prop_assert!(seen.insert((r, b.chunk)),
                             "duplicate pair ({r},{})", b.chunk);
            }
        }
        let want: usize = case.sets.iter().map(|s| s.len()).sum();
        prop_assert!(seen.len() == want, "{} pairs vs {} expected",
                     seen.len(), want);
        prop_assert!(stats.pairs == want, "stats.pairs mismatch");
        // determinism
        let (again, _) = form_batches(&case.sets, case.max_batch);
        prop_assert!(again == batches, "non-deterministic batching");
        Ok(())
    });
}

#[derive(Debug, Clone)]
struct TopKCase {
    scores: Vec<f32>,
    k: usize,
}

impl Case for TopKCase {
    fn shrink(&self) -> Vec<TopKCase> {
        if self.scores.len() > 1 {
            vec![TopKCase {
                scores: self.scores[..self.scores.len() / 2].to_vec(),
                k: self.k.min(self.scores.len() / 2).max(1),
            }]
        } else {
            Vec::new()
        }
    }
}

#[test]
fn prop_top_k_matches_sort() {
    check(
        "topk-vs-sort",
        Config::default(),
        |rng| {
            let n = rng.range(1, 300);
            let scores =
                (0..n).map(|_| rng.normal() as f32).collect::<Vec<_>>();
            TopKCase { k: rng.range(1, n + 1), scores }
        },
        |case| {
            let got = top_k_indices(&case.scores, case.k);
            // reference: full sort
            let mut idx: Vec<usize> = (0..case.scores.len()).collect();
            idx.sort_by(|&a, &b| {
                case.scores[b].partial_cmp(&case.scores[a]).unwrap()
            });
            let mut want = idx[..case.k.min(idx.len())].to_vec();
            want.sort_unstable();
            // ties can make membership differ; compare score multisets
            let sum_got: f32 = got.iter().map(|&i| case.scores[i]).sum();
            let sum_want: f32 = want.iter().map(|&i| case.scores[i]).sum();
            prop_assert!(got.len() == want.len(), "size mismatch");
            prop_assert!((sum_got - sum_want).abs() < 1e-3,
                         "top-k scores differ: {sum_got} vs {sum_want}");
            // ascending + unique
            for w in got.windows(2) {
                prop_assert!(w[0] < w[1], "not ascending/unique");
            }
            Ok(())
        },
    );
}

#[derive(Debug, Clone)]
struct AllocTrace {
    ops: Vec<(bool, usize)>, // (append?, tokens) else release request idx
}

impl Case for AllocTrace {
    fn shrink(&self) -> Vec<AllocTrace> {
        if self.ops.len() > 1 {
            vec![
                AllocTrace { ops: self.ops[..self.ops.len() / 2].to_vec() },
                AllocTrace { ops: self.ops[1..].to_vec() },
            ]
        } else {
            Vec::new()
        }
    }
}

#[test]
fn prop_page_pool_never_leaks() {
    check(
        "pagepool-no-leak",
        Config { cases: 40, ..Default::default() },
        |rng| {
            let n = rng.range(1, 60);
            AllocTrace {
                ops: (0..n)
                    .map(|_| (rng.f64() < 0.7, rng.range(1, 30)))
                    .collect(),
            }
        },
        |case| {
            let chunk = 8;
            let mut pool = PagePool::new(10_000, chunk, 2, 4);
            let mut rng = Rng::new(1);
            let mut reqs: Vec<RequestKv> = Vec::new();
            let mut expected_tokens: Vec<usize> = Vec::new();
            for &(is_append, n) in &case.ops {
                if is_append || reqs.is_empty() {
                    let mut kv = RequestKv::new(2, 0);
                    let shape = [n, 2, 4];
                    let mut k = vec![0f32; n * 8];
                    let mut v = vec![0f32; n * 8];
                    rng.fill_normal_f32(&mut k);
                    rng.fill_normal_f32(&mut v);
                    kv.append(
                        &mut pool,
                        &[
                            (Tensor::f32(&shape, k.clone()),
                             Tensor::f32(&shape, v.clone())),
                            (Tensor::f32(&shape, k), Tensor::f32(&shape, v)),
                        ],
                    )
                    .map_err(|e| e.to_string())?;
                    reqs.push(kv);
                    expected_tokens.push(n);
                } else {
                    let i = n % reqs.len();
                    let mut kv = reqs.swap_remove(i);
                    expected_tokens.swap_remove(i);
                    kv.release(&mut pool);
                }
            }
            // accounting: allocated pages == sum of live requests' pages
            let want: usize = expected_tokens
                .iter()
                .map(|&t| 2 * t.div_ceil(chunk))
                .sum();
            prop_assert!(pool.allocated() == want,
                         "allocated {} vs expected {}", pool.allocated(),
                         want);
            for mut kv in reqs {
                kv.release(&mut pool);
            }
            prop_assert!(pool.allocated() == 0, "leak after release");
            Ok(())
        },
    );
}

#[derive(Debug, Clone)]
struct MergeCase {
    n_parts: usize,
    seed: u64,
}

impl Case for MergeCase {
    fn shrink(&self) -> Vec<MergeCase> {
        if self.n_parts > 2 {
            vec![MergeCase { n_parts: self.n_parts / 2, seed: self.seed }]
        } else {
            Vec::new()
        }
    }
}

#[test]
fn prop_lse_merge_equals_monolithic() {
    // Attention over one T-token context == merge of its chunk partials,
    // for random chunkings — the exactness core of the whole system.
    check(
        "merge-exactness",
        Config { cases: 30, ..Default::default() },
        |rng| MergeCase { n_parts: rng.range(1, 9), seed: rng.next_u64() },
        |case| {
            let be = NativeBackend::new(ModelConfig::tiny(), 64);
            let mut rng = Rng::new(case.seed);
            let t = case.n_parts * 16;
            let mk = |rng: &mut Rng, shape: &[usize]| {
                let mut d = vec![0f32; shape.iter().product()];
                rng.fill_normal_f32(&mut d);
                Tensor::f32(shape, d)
            };
            let q = mk(&mut rng, &[2, 4, 16]);
            let k = mk(&mut rng, &[t, 2, 16]);
            let v = mk(&mut rng, &[t, 2, 16]);
            let q_pos = [rng.range(0, t + 5) as i32, (t as i32) + 100];
            let whole = be
                .chunk_attn(&q, &k, &v, &q_pos, 0, t as i32)
                .map_err(|e| e.to_string())?;
            let mut parts = Vec::new();
            for p in 0..case.n_parts {
                let s = p * 16;
                parts.push(
                    be.chunk_attn(
                        &q, &k.slice0(s, s + 16), &v.slice0(s, s + 16),
                        &q_pos, s as i32, 16,
                    )
                    .map_err(|e| e.to_string())?,
                );
            }
            let merged = moska::attention::merge_many(&parts);
            let a = native::finalize(&whole);
            let b = native::finalize(&merged);
            let d = a.max_abs_diff(&b);
            prop_assert!(d < 1e-4, "chunked != monolithic: diff {d}");
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    use moska::util::json::Json;

    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.normal() * 1e3).round()),
            3 => {
                let n = rng.range(0, 12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            char::from_u32(rng.range(32, 1000) as u32)
                                .unwrap_or('x')
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.range(0, 5))
                    .map(|_| gen_json(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.range(0, 5))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    check(
        "json-roundtrip",
        Config { cases: 200, ..Default::default() },
        |rng| rng.next_u64() as usize,
        |&seed| {
            let mut rng = Rng::new(seed as u64);
            let v = gen_json(&mut rng, 3);
            let s = v.to_string();
            let back = Json::parse(&s).map_err(|e| format!("{e} in {s}"))?;
            prop_assert!(back == v, "roundtrip mismatch: {s}");
            Ok(())
        },
    );
}

#[test]
fn prop_scheduler_conserves_requests() {
    use moska::scheduler::{ReqMeta, StepScheduler};

    check(
        "scheduler-conservation",
        Config { cases: 50, ..Default::default() },
        |rng| Pair(rng.range(1, 50), rng.range(1, 8)),
        |&Pair(n, max_batch)| {
            let mut s = StepScheduler::new(max_batch);
            for id in 0..n {
                s.enqueue(id, ReqMeta::default());
            }
            let mut completed = std::collections::HashSet::new();
            let mut guard = 0;
            while !s.is_idle() {
                guard += 1;
                prop_assert!(guard < 10_000, "scheduler livelock");
                s.tick();
                prop_assert!(s.live().len() <= max_batch, "batch overflow");
                // complete the first live request each "step"
                if let Some(&id) = s.live().first() {
                    completed.insert(id);
                    s.retire(&[id]);
                }
            }
            prop_assert!(completed.len() == n,
                         "{} completed vs {n}", completed.len());
            Ok(())
        },
    );
}

#[derive(Debug, Clone)]
struct SchedOps {
    ops: Vec<(u8, usize)>,
    max_batch: usize,
}

impl Case for SchedOps {
    fn shrink(&self) -> Vec<SchedOps> {
        if self.ops.len() > 1 {
            vec![
                SchedOps {
                    ops: self.ops[..self.ops.len() / 2].to_vec(),
                    max_batch: self.max_batch,
                },
                SchedOps {
                    ops: self.ops[1..].to_vec(),
                    max_batch: self.max_batch,
                },
            ]
        } else {
            Vec::new()
        }
    }
}

/// Mirror the engine's per-tick KV bookkeeping: fresh KV on (first)
/// admission, one chunk appended per prefill assignment, one token per
/// decode row.
fn sched_run_tick(
    s: &mut moska::scheduler::StepScheduler,
    pool: &mut PagePool,
    kvs: &mut std::collections::HashMap<usize, RequestKv>,
    rng: &mut Rng,
) -> Result<(), String> {
    let t = s.tick();
    for id in &t.admitted {
        kvs.entry(*id).or_insert_with(|| RequestKv::new(2, 0));
    }
    let mut grow = |kvs: &mut std::collections::HashMap<usize, RequestKv>,
                    pool: &mut PagePool,
                    rng: &mut Rng,
                    id: usize,
                    n: usize|
     -> Result<(), String> {
        let kv = kvs.get_mut(&id).ok_or("kv append for unknown id")?;
        let shape = [n, 2, 4];
        let mut k = vec![0f32; n * 8];
        let mut v = vec![0f32; n * 8];
        rng.fill_normal_f32(&mut k);
        rng.fill_normal_f32(&mut v);
        kv.append(
            pool,
            &[
                (Tensor::f32(&shape, k.clone()),
                 Tensor::f32(&shape, v.clone())),
                (Tensor::f32(&shape, k), Tensor::f32(&shape, v)),
            ],
        )
        .map_err(|e| e.to_string())
    };
    for pa in &t.prefill {
        grow(kvs, pool, rng, pa.id, pa.end - pa.start)?;
    }
    for id in &t.decode {
        grow(kvs, pool, rng, *id, 1)?;
    }
    Ok(())
}

/// The serving loop's page-conservation invariant under randomized
/// arrival / retire / preempt (hold and recompute flavors) / cancel:
/// every page is either free or owned by exactly one live KV, the
/// active batch never overflows, and a full drain returns the pool to
/// empty.
#[test]
fn prop_scheduler_preempt_page_accounting() {
    use moska::scheduler::{Phase, ReqMeta, StepScheduler};

    check(
        "scheduler-preempt-pages",
        Config { cases: 40, ..Default::default() },
        |rng| {
            let n = rng.range(4, 60);
            SchedOps {
                ops: (0..n)
                    .map(|_| (rng.range(0, 4) as u8, rng.range(0, 1000)))
                    .collect(),
                max_batch: rng.range(1, 6),
            }
        },
        |case| {
            let mut pool = PagePool::new(10_000, 8, 2, 4);
            let mut s = StepScheduler::new(case.max_batch)
                .with_budget(8, 8);
            let mut kvs = std::collections::HashMap::new();
            let mut known: Vec<usize> = Vec::new();
            let mut next_id = 0usize;
            let mut rng = Rng::new(7);
            for &(kind, val) in &case.ops {
                match kind {
                    0 => {
                        let prompt_tokens = rng.range(1, 20);
                        s.enqueue(next_id, ReqMeta {
                            prompt_tokens,
                            ..Default::default()
                        });
                        known.push(next_id);
                        next_id += 1;
                    }
                    1 => {
                        // force-preempt a live request; odd ids take the
                        // recompute flavor (pages released, prefill
                        // restarts), even ids hold their pages
                        let live = s.live();
                        if let Some(&id) =
                            live.get(val % live.len().max(1))
                        {
                            prop_assert!(s.force_preempt(id),
                                         "live id not preemptible");
                            if id % 2 == 1 {
                                if let Some(mut kv) = kvs.remove(&id) {
                                    kv.release(&mut pool);
                                }
                                s.reset_progress(id);
                            }
                        }
                    }
                    2 => {
                        if let Some(&id) = s.live().first() {
                            s.retire(&[id]);
                            known.retain(|&k| k != id);
                            if let Some(mut kv) = kvs.remove(&id) {
                                kv.release(&mut pool);
                            }
                        }
                    }
                    _ => {
                        if !known.is_empty() {
                            let id = known[val % known.len()];
                            prop_assert!(s.cancel(id),
                                         "cancel of known id failed");
                            known.retain(|&k| k != id);
                            if let Some(mut kv) = kvs.remove(&id) {
                                kv.release(&mut pool);
                            }
                        }
                    }
                }
                sched_run_tick(&mut s, &mut pool, &mut kvs, &mut rng)?;
                prop_assert!(s.live().len() <= case.max_batch,
                             "batch overflow");
                let want: usize =
                    kvs.values().map(|kv| kv.page_count()).sum();
                prop_assert!(pool.allocated() == want,
                             "pages_live {} != owned {}",
                             pool.allocated(), want);
                prop_assert!(
                    pool.allocated() + pool.available() == pool.capacity(),
                    "page conservation broken: {} + {} != {}",
                    pool.allocated(), pool.available(), pool.capacity()
                );
            }
            // drain: finish everything, then the pool must be empty
            let mut guard = 0;
            while !s.is_idle() {
                guard += 1;
                prop_assert!(guard < 10_000, "drain livelock");
                sched_run_tick(&mut s, &mut pool, &mut kvs, &mut rng)?;
                let done: Vec<usize> = s
                    .live()
                    .iter()
                    .copied()
                    .filter(|&id| s.phase(id) == Some(Phase::Decode))
                    .collect();
                for id in done {
                    s.retire(&[id]);
                    known.retain(|&k| k != id);
                    if let Some(mut kv) = kvs.remove(&id) {
                        kv.release(&mut pool);
                    }
                }
            }
            prop_assert!(known.is_empty() && kvs.is_empty(),
                         "requests left behind");
            prop_assert!(pool.allocated() == 0,
                         "pages leak after drain: {}", pool.allocated());
            Ok(())
        },
    );
}

/// Local pair wrapper (orphan rule: can't impl moska's trait on a tuple).
#[derive(Debug, Clone, Copy)]
struct Pair(usize, usize);

impl Case for Pair {
    fn shrink(&self) -> Vec<Pair> {
        let mut v = Vec::new();
        if self.0 > 1 {
            v.push(Pair(self.0 / 2, self.1));
        }
        if self.1 > 1 {
            v.push(Pair(self.0, self.1 / 2));
        }
        v
    }
}
