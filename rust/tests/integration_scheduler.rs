//! Deterministic serving-loop scheduler harness: scripted arrival
//! sequences replay to identical tick-by-tick batch composition (no
//! clocks, no sleeps), chunked prefill interleaves with decode,
//! weighted fairness converges to the configured shares, and
//! preempt-and-resume keeps generated tokens bit-identical under both
//! preemption policies (Hold and Recompute) at the engine level.

use std::collections::HashMap;

use moska::config::{ModelConfig, ServingConfig};
use moska::engine::Engine;
use moska::kvcache::SharedStore;
use moska::model::sampling::Sampler;
use moska::model::Weights;
use moska::runtime::NativeBackend;
use moska::scheduler::{Phase, PreemptPolicy, Priority, ReqMeta,
                       StepScheduler, Tick};

const CHUNK: usize = 64;

fn meta(tenant: &str, weight: f64, priority: Priority,
        prompt: usize) -> ReqMeta {
    ReqMeta {
        tenant: tenant.to_string(),
        weight,
        priority,
        prompt_tokens: prompt,
    }
}

// ------------------------------------------------ scripted tick replay

/// One scripted scheduler session: arrivals, retires, and forced
/// preemptions keyed to tick indices. Pure state machine — replaying
/// the script must reproduce every tick verbatim.
fn drive_script(ticks: usize) -> Vec<Tick> {
    let mut s = StepScheduler::new(3).with_budget(16, 8);
    let mut out = Vec::new();
    for i in 0..ticks {
        match i {
            0 => {
                s.enqueue(0, meta("a", 1.0, Priority::Standard, 24));
                s.enqueue(1, meta("b", 2.0, Priority::Standard, 16));
            }
            2 => {
                s.enqueue(2, meta("a", 1.0, Priority::Batch, 8));
                s.enqueue(3, meta("c", 1.0, Priority::Interactive, 8));
            }
            4 => {
                // force a hold-style preemption mid-flight
                let id = *s.live().first().unwrap();
                assert!(s.force_preempt(id));
            }
            6 => {
                if let Some(&id) = s.live().first() {
                    s.retire(&[id]);
                }
                s.enqueue(4, meta("b", 2.0, Priority::Standard, 8));
            }
            8 => {
                // recompute-style: back to the queue with progress reset
                if let Some(&id) = s.live().last() {
                    assert!(s.force_preempt(id));
                    s.reset_progress(id);
                }
            }
            _ => {}
        }
        out.push(s.tick());
    }
    out
}

/// The whole harness is clock-free: two replays of the same script
/// produce byte-identical tick streams.
#[test]
fn scripted_replay_is_deterministic() {
    let a = drive_script(12);
    let b = drive_script(12);
    assert_eq!(a, b, "tick streams diverged between replays");
    // the script actually exercised the interesting paths
    assert!(a.iter().any(|t| !t.prefill.is_empty()));
    assert!(a.iter().any(|t| !t.decode.is_empty()));
    assert!(a.iter().any(|t| t.prefill.len() > 1
                || (!t.prefill.is_empty() && !t.decode.is_empty())),
            "no tick mixed prefill with decode or batched chunks");
}

/// Scripted decode-budget run: eight decode-phase requests (zero-length
/// prompts owe no prefill) over a 4-token step budget, one tenant per
/// request — four heavy (weight 3) and four light (weight 1) — each
/// request retiring after 12 decoded tokens. Per-request tenants make
/// the deficit key rotate over every row (within one tenant the seq
/// tie-break is intentionally FIFO instead).
fn drive_decode_budget() -> (Vec<Tick>, Vec<usize>) {
    let mut s = StepScheduler::new(8).with_budget(4, 4);
    for id in 0..8usize {
        let (tenant, weight) = if id < 4 {
            (format!("h{id}"), 3.0)
        } else {
            (format!("l{id}"), 1.0)
        };
        s.enqueue(id, meta(&tenant, weight, Priority::Standard, 0));
    }
    let mut decoded = [0usize; 8];
    let mut ticks = Vec::new();
    let mut retired = Vec::new();
    for _ in 0..64 {
        let t = s.tick();
        let done: Vec<usize> = t
            .decode
            .iter()
            .copied()
            .filter(|&id| {
                decoded[id] += 1;
                decoded[id] == 12
            })
            .collect();
        s.retire(&done);
        retired.extend(done);
        ticks.push(t);
        if retired.len() == 8 {
            break;
        }
    }
    (ticks, retired)
}

/// Decode-side token budget at the harness level: with twice as many
/// live decode rows as the budget covers, replays are tick-identical,
/// no tick exceeds the budget, bandwidth splits by tenant weight, no
/// row starves, and the heavy tenant's requests all finish first.
#[test]
fn decode_budget_replays_deterministically_and_respects_weights() {
    let (a, done_a) = drive_decode_budget();
    let (b, done_b) = drive_decode_budget();
    assert_eq!(a, b, "decode-budget tick streams diverged");
    assert_eq!(done_a, done_b, "retirement order diverged");
    assert_eq!(done_a.len(), 8, "not every request finished: {done_a:?}");
    assert!(a.iter().all(|t| t.decode.len() <= 4),
            "a tick decoded past the 4-token budget");
    assert!(a.iter().take(8).all(|t| t.decode.len() == 4),
            "eight live decoders over a 4-token budget must saturate it");
    // pre-retirement window: heavy (weight 3) out-decodes light
    // (weight 1) and every live row still gets slots
    let (mut heavy, mut light) = (0usize, 0usize);
    let mut seen = std::collections::HashSet::new();
    for t in a.iter().take(8) {
        for &id in &t.decode {
            seen.insert(id);
            if id < 4 {
                heavy += 1;
            } else {
                light += 1;
            }
        }
    }
    assert!(heavy >= 2 * light && light > 0,
            "3:1 weights not honored: heavy={heavy} light={light}");
    assert_eq!(seen.len(), 8, "a live decode row starved: {seen:?}");
    // 3x the bandwidth at the same token count → heavy retires first
    assert!(done_a[..4].iter().all(|&id| id < 4),
            "a light request finished before the heavy ones: {done_a:?}");
}

/// A long prompt shares every tick with live decode rows instead of
/// monopolizing the loop: decode appears in each tick of the long
/// prefill window, and the long prompt needs several ticks to finish.
#[test]
fn chunked_prefill_interleaves_with_decode_rows() {
    let mut s = StepScheduler::new(4).with_budget(8, 4);
    s.enqueue(0, meta("a", 1.0, Priority::Standard, 4));
    s.tick(); // admit + whole-prompt prefill of the short request
    assert_eq!(s.phase(0), Some(Phase::Decode));
    s.enqueue(1, meta("b", 1.0, Priority::Standard, 20));
    let mut prefill_ticks = 0;
    loop {
        let t = s.tick();
        if s.phase(1) == Some(Phase::Decode) {
            break;
        }
        prefill_ticks += 1;
        assert_eq!(t.decode, vec![0],
                   "decode starved during chunked prefill");
        assert_eq!(t.prefill.len(), 1, "budget admits one chunk per tick");
        assert_eq!(t.prefill[0].id, 1);
    }
    assert_eq!(prefill_ticks, 4, "20 tokens / 4-token chunks, one per tick");
}

/// Weighted fair sharing: two always-backlogged tenants with 3:1
/// weights split prefill bandwidth 3:1, within one chunk of ideal.
#[test]
fn weighted_fairness_converges_to_shares() {
    let mut s = StepScheduler::new(4).with_budget(8, 8);
    s.enqueue(0, meta("heavy", 3.0, Priority::Standard, 400));
    s.enqueue(1, meta("light", 1.0, Priority::Standard, 400));
    let (mut heavy, mut light) = (0usize, 0usize);
    for _ in 0..40 {
        for pa in s.tick().prefill {
            let n = pa.end - pa.start;
            if pa.id == 0 {
                heavy += n;
            } else {
                light += n;
            }
        }
    }
    assert_eq!(heavy + light, 320, "one 8-token chunk per tick");
    assert!((heavy as i64 - 240).unsigned_abs() <= 8,
            "3:1 split violated: heavy={heavy} light={light}");
}

/// Full-batch priority preemption replays deterministically: the
/// interactive arrival displaces the latest lowest-class live request,
/// which re-admits (ahead of its class peers) once a slot frees.
#[test]
fn priority_preemption_and_victim_resume() {
    let mut s = StepScheduler::new(2).with_budget(16, 8);
    s.enqueue(0, meta("a", 1.0, Priority::Batch, 8));
    s.enqueue(1, meta("a", 1.0, Priority::Batch, 8));
    let t = s.tick();
    assert_eq!(t.admitted, vec![0, 1]);
    s.enqueue(2, meta("b", 1.0, Priority::Interactive, 8));
    let t = s.tick();
    assert_eq!(t.preempted, vec![1], "latest batch-class request evicted");
    assert_eq!(t.admitted, vec![2]);
    assert_eq!(s.live(), &[0, 2]);
    // victim keeps its prefill progress (hold) and resumes when the
    // interactive request retires
    assert_eq!(s.phase(1), Some(Phase::Decode),
               "victim's completed prefill must survive preemption");
    s.retire(&[2]);
    let t = s.tick();
    assert_eq!(t.admitted, vec![1]);
    assert!(t.decode.contains(&1));
}

// -------------------------------------- engine-level preempt identity

/// Synthetic engine with explicit serving-loop knobs; `prefill_chunk`
/// is kept a multiple of the prefill slab (max_batch.min(32)) so
/// chunked and unchunked prefill issue identical forward slabs.
fn engine(policy: PreemptPolicy, step_tokens: usize,
          prefill_chunk: usize) -> Engine {
    let model = ModelConfig::tiny();
    let cfg = ServingConfig {
        top_k: Some(2),
        max_batch: 8,
        exec_threads: 1,
        step_tokens,
        prefill_chunk,
        preempt_policy: policy,
        ..Default::default()
    };
    let be = NativeBackend::with_threads(model.clone(), CHUNK, 1);
    let weights = Weights::synthetic(model, 0xF1A4);
    let mut eng = Engine::new(
        Box::new(be), weights, SharedStore::empty(CHUNK), cfg, 1024,
    );
    let tokens: Vec<i32> =
        (0..4 * CHUNK).map(|i| (i % 251) as i32).collect();
    eng.register_domain("dom", &tokens).expect("register domain");
    eng
}

fn submit_mix(eng: &mut Engine) {
    // one long prompt (3 chunks of 16) + two shorts, all greedy
    let long: Vec<i32> = (0..48).map(|i| (i % 200) as i32).collect();
    let s1: Vec<i32> = (0..10).map(|i| (3 * i % 190) as i32).collect();
    let s2: Vec<i32> = (0..12).map(|i| (7 * i % 180) as i32).collect();
    eng.submit(Some("dom"), long, 6, Sampler::Greedy).unwrap();
    eng.submit(Some("dom"), s1, 6, Sampler::Greedy).unwrap();
    eng.submit(Some("dom"), s2, 6, Sampler::Greedy).unwrap();
}

/// Drive to completion, optionally preempting request 0 once: either
/// after a fixed step count (`after_steps`) or once it has emitted
/// `after_tokens` tokens (mid-decode). Returns id → token stream.
fn run_engine(mut eng: Engine, after_steps: Option<usize>,
              after_tokens: Option<usize>) -> HashMap<usize, Vec<i32>> {
    submit_mix(&mut eng);
    let mut emitted0 = 0usize;
    let mut preempted = false;
    let mut steps = 0usize;
    loop {
        let more = eng.step().expect("engine step");
        steps += 1;
        emitted0 += eng
            .take_emitted()
            .iter()
            .filter(|(id, _)| *id == 0)
            .count();
        let due = match (after_steps, after_tokens) {
            (Some(n), _) => steps == n,
            (_, Some(k)) => emitted0 >= k,
            _ => false,
        };
        if due && !preempted {
            preempted = true;
            assert!(eng.preempt(0).expect("preempt"),
                    "request 0 was not live at the preemption point");
        }
        if !more {
            break;
        }
    }
    if after_steps.is_some() || after_tokens.is_some() {
        assert!(preempted, "preemption point never reached");
    }
    eng.take_results()
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect()
}

/// Fixed scheduler decisions aside, generated tokens are a pure
/// function of (prompt, weights): chunked, unchunked, and
/// preempt-resumed runs all emit bit-identical streams. Covers both
/// policies at both preemption points (mid-prefill and mid-decode) —
/// Recompute exercises page release + re-prefill + forced replay via
/// `RequestKv::rollback_uncommitted`.
#[test]
fn preempt_resume_token_bit_identity() {
    let baseline = run_engine(engine(PreemptPolicy::Hold, 16, 16),
                              None, None);
    assert_eq!(baseline.len(), 3);
    for (id, toks) in &baseline {
        assert_eq!(toks.len(), 6, "request {id} token count");
    }

    // chunking off entirely — same tokens (slab-aligned prefill)
    let unchunked = run_engine(engine(PreemptPolicy::Hold, 0, 0),
                               None, None);
    assert_eq!(baseline, unchunked,
               "chunked vs unchunked prefill diverged");

    for policy in [PreemptPolicy::Hold, PreemptPolicy::Recompute] {
        // mid-prefill: request 0 has chunks left after the first step
        let got = run_engine(engine(policy, 16, 16), Some(1), None);
        assert_eq!(baseline, got,
                   "{policy:?} mid-prefill preempt changed tokens");
        // mid-decode: request 0 already generated a few tokens
        let got = run_engine(engine(policy, 16, 16), None, Some(3));
        assert_eq!(baseline, got,
                   "{policy:?} mid-decode preempt changed tokens");
    }
}

/// Preemption accounting: a Recompute preempt releases the request's
/// pages while queued; a Hold preempt keeps them. Either way the pool
/// drains to zero after completion.
#[test]
fn preempt_policies_page_accounting() {
    for (policy, expect_drop) in
        [(PreemptPolicy::Hold, false), (PreemptPolicy::Recompute, true)]
    {
        let mut eng = engine(policy, 16, 16);
        submit_mix(&mut eng);
        // step until request 0 is decoding (its pages are maximal)
        let mut guard = 0;
        while eng.sched.phase(0) != Some(Phase::Decode) {
            eng.step().expect("step");
            guard += 1;
            assert!(guard < 100, "request 0 never reached decode");
        }
        let before = eng.pool.allocated();
        assert!(before > 0);
        assert!(eng.preempt(0).expect("preempt"));
        let after = eng.pool.allocated();
        if expect_drop {
            assert!(after < before,
                    "{policy:?}: pages not released ({before} -> {after})");
        } else {
            assert_eq!(after, before,
                       "{policy:?}: held pages changed ({before} -> {after})");
        }
        while eng.step().expect("step") {}
        assert_eq!(eng.take_results().len(), 3);
        assert_eq!(eng.pool.allocated(), 0, "pages leak after drain");
    }
}
