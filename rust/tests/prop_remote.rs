//! Codec property tests: the wire roundtrip is bit-identical for
//! randomized plans/partials, and corrupted or truncated frames fail
//! with a typed [`CodecError`] instead of panicking.

use moska::kvcache::shared_store::DomainPlannerState;
use moska::plan::{plan_gemm_calls, plan_unique_spans, SharedGroupPlan,
                  StepPlan, UniqueRowPlan};
use moska::remote::codec::{frame_bytes, read_frame, CodecError,
                           ExecSharedReq, ServerSpan, StoreSync, TraceCtx,
                           WireMsg, CODEC_VERSION};
use moska::router::ChunkSet;
use moska::runtime::native::Partials;
use moska::tensor::{KvDtype, Tensor};
use moska::util::prop::{check, Case, Config};
use moska::util::rng::Rng;

// ------------------------------------------------------------ generators

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let mut d = vec![0f32; shape.iter().product()];
    rng.fill_normal_f32(&mut d);
    // sprinkle the special values the fabric actually ships (-inf LSE
    // identities, exact zeros) — NaN is excluded only because NaN != NaN
    // would make the equality assertion vacuous
    if !d.is_empty() {
        let n = d.len();
        d[rng.below(n as u64) as usize] = f32::NEG_INFINITY;
        d[rng.below(n as u64) as usize] = 0.0;
        d[rng.below(n as u64) as usize] = -0.0;
        d[rng.below(n as u64) as usize] = f32::MIN_POSITIVE / 2.0; // denormal
    }
    Tensor::f32(shape, d)
}

fn rand_sets(rng: &mut Rng, b: usize, n_chunks: usize) -> Vec<ChunkSet> {
    (0..b)
        .map(|_| {
            let mut set: ChunkSet = (0..n_chunks)
                .filter(|_| rng.below(2) == 0)
                .collect();
            if set.is_empty() && rng.below(2) == 0 {
                set.push(rng.below(n_chunks as u64) as usize);
            }
            set
        })
        .collect()
}

fn rand_group_plan(rng: &mut Rng) -> SharedGroupPlan {
    let b = 1 + rng.below(6) as usize;
    let n_chunks = 1 + rng.below(10) as usize;
    let chunk = 8usize;
    let bases: Vec<i32> = (0..n_chunks).map(|c| (c * chunk) as i32).collect();
    let sets = rand_sets(rng, b, n_chunks);
    let position_independent = rng.below(4) == 0;
    let (calls, stats) = plan_gemm_calls(&sets, 32, chunk, &bases,
                                         8 * (1 + rng.below(4) as usize),
                                         position_independent);
    SharedGroupPlan {
        domain: format!("dom{}", rng.below(100)),
        rows: (0..b).collect(),
        q_pos: (0..b).map(|_| rng.below(10_000) as i32 - 1).collect(),
        sets,
        calls,
        pairs: stats.pairs,
        reads: stats.chunk_reads.max(stats.calls),
    }
}

fn rand_step_plan(rng: &mut Rng) -> StepPlan {
    let b = 1 + rng.below(5) as usize;
    let groups = (0..rng.below(3)).map(|_| rand_group_plan(rng)).collect();
    let unique = (0..b)
        .map(|_| UniqueRowPlan {
            spans: plan_unique_spans(
                rng.below(100) as usize, rng.below(64) as usize, 8,
                8 * (1 + rng.below(4) as usize),
            ),
        })
        .collect();
    StepPlan {
        b,
        pos: (0..b).map(|_| rng.below(4096) as i32).collect(),
        shared_groups: groups,
        route_live: rng.below(2) == 0,
        unique,
        unique_work: rng.below(1 << 20) as usize,
        max_batch: 1 + rng.below(64) as usize,
        position_independent: rng.below(2) == 0,
    }
}

fn rand_planner_state(rng: &mut Rng) -> DomainPlannerState {
    let nc = 1 + rng.below(6) as usize;
    let layers = 1 + rng.below(3) as usize;
    DomainPlannerState {
        name: format!("dom{}", rng.below(100)),
        n_tokens: nc * 8,
        chunk_bases: (0..nc).map(|c| (c * 8) as i32).collect(),
        embs: (0..layers).map(|_| rand_tensor(rng, &[nc, 2, 8])).collect(),
    }
}

fn rand_msg(rng: &mut Rng) -> WireMsg {
    match rng.below(5) {
        0 => WireMsg::ExecShared(ExecSharedReq {
            layer: rng.below(8) as usize,
            q: rand_tensor(rng, &[1 + rng.below(4) as usize, 4, 8]),
            plan: rand_group_plan(rng),
            // v5 trace context is optional — cover both layouts
            trace: if rng.below(2) == 0 {
                None
            } else {
                Some(TraceCtx {
                    trace_id: rng.next_u64(),
                    parent_span: rng.next_u64(),
                })
            },
        }),
        1 => WireMsg::StepPlan(rand_step_plan(rng)),
        2 => {
            let n = 1 + rng.below(4) as usize;
            WireMsg::Partials {
                parts: (0..n)
                    .map(|_| Partials {
                        o: rand_tensor(rng, &[1, 4, 8]),
                        m: rand_tensor(rng, &[1, 4]),
                        l: rand_tensor(rng, &[1, 4]),
                    })
                    .collect(),
                exec_ns: rng.next_u64(),
                trace_id: rng.next_u64(),
                spans: (0..rng.below(3))
                    .map(|i| ServerSpan {
                        name: format!("span{i}"),
                        start_ns: rng.next_u64(),
                        dur_ns: rng.next_u64(),
                    })
                    .collect(),
            }
        }
        3 => WireMsg::SyncState(StoreSync {
            chunk: 8,
            digest: rng.next_u64(),
            kv_dtype: KvDtype::from_code(rng.below(4) as u8).unwrap(),
            domains: (0..rng.below(4))
                .map(|_| rand_planner_state(rng))
                .collect(),
        }),
        _ => WireMsg::Error(format!("error {}", rng.below(1000))),
    }
}

// ----------------------------------------------------------- the wrapper

/// A generated message plus its frame bytes (shrinks by truncation are
/// handled in the dedicated properties; no structural shrinking here).
#[derive(Debug, Clone)]
struct FrameCase {
    msg: WireMsg,
    bytes: Vec<u8>,
}

impl Case for FrameCase {}

fn gen_case(rng: &mut Rng) -> FrameCase {
    let msg = rand_msg(rng);
    let bytes = frame_bytes(&msg);
    FrameCase { msg, bytes }
}

// ---------------------------------------------------------- the properties

#[test]
fn roundtrip_is_bit_identical() {
    check("codec-roundtrip", Config::default(), gen_case, |case| {
        let (back, n) = read_frame(&mut std::io::Cursor::new(&case.bytes))
            .map_err(|e| format!("decode failed: {e}"))?;
        if n != case.bytes.len() {
            return Err(format!("consumed {n} of {}", case.bytes.len()));
        }
        if back != case.msg {
            return Err("roundtrip changed the message".into());
        }
        Ok(())
    });
}

/// A frame plus a mutation site (byte offset + bit, or a cut length).
#[derive(Debug, Clone)]
struct MutatedCase {
    case: FrameCase,
    at: usize,
    bit: u8,
}

impl Case for MutatedCase {}

#[test]
fn corrupted_frames_fail_typed_never_panic() {
    // flip one byte at a randomized offset: decode must return Err (or,
    // in the astronomically unlikely CRC-collision case, not equal the
    // original) — and must never panic
    check(
        "codec-corruption",
        Config { cases: 128, ..Config::default() },
        |rng| {
            let case = gen_case(rng);
            let at = rng.below(case.bytes.len() as u64) as usize;
            let bit = 1u8 << rng.below(8);
            MutatedCase { case, at, bit }
        },
        |m| {
            let mut bytes = m.case.bytes.clone();
            bytes[m.at] ^= m.bit;
            match read_frame(&mut std::io::Cursor::new(&bytes)) {
                Err(_) => Ok(()),
                Ok((back, _)) if back != m.case.msg => Ok(()),
                Ok(_) => Err(format!(
                    "flipping byte {} bit {:#04x} went unnoticed",
                    m.at, m.bit,
                )),
            }
        },
    );
}

#[test]
fn truncated_frames_fail_typed_never_panic() {
    check(
        "codec-truncation",
        Config { cases: 64, ..Config::default() },
        |rng| {
            let case = gen_case(rng);
            let at = rng.below(case.bytes.len() as u64) as usize;
            MutatedCase { case, at, bit: 0 }
        },
        |m| {
            let err = match read_frame(
                &mut std::io::Cursor::new(&m.case.bytes[..m.at]),
            ) {
                Err(e) => e,
                Ok(_) => {
                    return Err(format!("decoded a {}-byte prefix", m.at))
                }
            };
            match err {
                CodecError::Truncated => Ok(()),
                other => Err(format!("unexpected error {other}")),
            }
        },
    );
}

#[test]
fn foreign_version_fails_before_payload() {
    check(
        "codec-version",
        Config { cases: 32, ..Config::default() },
        |rng| {
            let case = gen_case(rng);
            // any version but the real one is foreign
            let mut v = rng.below(60_000) as usize;
            if v == CODEC_VERSION as usize {
                v += 1;
            }
            MutatedCase { case, at: v, bit: 0 }
        },
        |m| {
            let mut bytes = m.case.bytes.clone();
            bytes[4..6].copy_from_slice(&(m.at as u16).to_le_bytes());
            match read_frame(&mut std::io::Cursor::new(&bytes)) {
                Err(CodecError::VersionMismatch { got, want }) => {
                    if got as usize == m.at && want == CODEC_VERSION {
                        Ok(())
                    } else {
                        Err(format!("wrong fields: got {got} want {want}"))
                    }
                }
                other => Err(format!("expected VersionMismatch, got \
                                      {other:?}")),
            }
        },
    );
}
