//! Remote shared-KV node integration tests — all loopback, no
//! artifacts: the synthetic store (`disagg::synthetic_store`) is
//! deterministic, so client and server build bit-identical state the way
//! two real processes would.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use moska::config::ModelConfig;
use moska::disagg::{synthetic_store, synthetic_weights, DisaggCluster,
                    SharedFabric, SYNTH_CHUNK, SYNTH_DOMAIN};
use moska::plan::SharedGroupPlan;
use moska::remote::codec::{self, HelloAck, WireMsg};
use moska::remote::{spawn_shared_node, RemoteFabric, TransportCfg};
use moska::runtime::native::Partials;
use moska::runtime::{Backend, NativeBackend};
use moska::tensor::Tensor;

fn native_be() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::with_threads(ModelConfig::tiny(), SYNTH_CHUNK,
                                         1))
}

fn test_cfg() -> TransportCfg {
    TransportCfg {
        connect_attempts: 20,
        reconnect_attempts: 20,
        connect_backoff: Duration::from_millis(25),
        connect_backoff_cap: Duration::from_millis(100),
        request_retries: 2,
        read_timeout: Duration::from_secs(2),
    }
}

fn trivial_plan(domain: &str) -> SharedGroupPlan {
    SharedGroupPlan {
        domain: domain.to_string(),
        rows: vec![0],
        q_pos: vec![100],
        sets: vec![vec![]],
        calls: vec![],
        pairs: 0,
        reads: 0,
    }
}

fn trivial_q() -> Tensor {
    Tensor::f32(&[1, 4, 16], vec![0.25; 64])
}

/// The acceptance criterion: `--remote` decode must be bit-identical to
/// the in-process run, over a real socket.
#[test]
fn remote_decode_bit_identical_to_local() {
    let shared = Arc::new(synthetic_store().unwrap());
    let addr =
        spawn_shared_node(native_be(), Arc::clone(&shared)).unwrap();

    let mut local = DisaggCluster::with_backends(
        native_be(), native_be(), synthetic_weights(),
        Arc::clone(&shared), Some(4), 32,
    );
    let pl = local.run_point(3, SYNTH_DOMAIN, 32, 4).unwrap();

    let mut fabric =
        RemoteFabric::connect(&addr.to_string(), test_cfg()).unwrap();
    let doms = vec![SYNTH_DOMAIN.to_string()];
    assert!(
        fabric
            .check_store(SYNTH_CHUNK, &doms, 0,
                         moska::tensor::KvDtype::F32)
            .is_err(),
        "a content-mismatched store must be refused at connect",
    );
    assert!(
        fabric
            .check_store(SYNTH_CHUNK, &doms, shared.content_digest(),
                         moska::tensor::KvDtype::F16)
            .is_err(),
        "a dtype-mismatched store must be refused at connect",
    );
    fabric
        .check_store(SYNTH_CHUNK, &doms, shared.content_digest(),
                     moska::tensor::KvDtype::F32)
        .unwrap();
    let mut remote = DisaggCluster::with_fabric(
        native_be(), Box::new(fabric), synthetic_weights(),
        Arc::clone(&shared), Some(4), 32,
    );
    let pr = remote.run_point(3, SYNTH_DOMAIN, 32, 4).unwrap();

    assert_eq!(pl.tokens, pr.tokens,
               "remote decode diverged from in-process decode");
    assert!(!pl.tokens.is_empty() && pl.tokens[0].len() == 4);

    // the work really crossed the wire
    let st = remote.fabric_stats().expect("remote fabric has stats");
    let frames =
        st.frames_sent.load(std::sync::atomic::Ordering::Relaxed);
    let layers = ModelConfig::tiny().n_layers;
    assert!(frames as usize >= 4 * layers,
            "only {frames} frames for {} layer-steps", 4 * layers);
    assert!(st.bytes_sent.load(std::sync::atomic::Ordering::Relaxed) > 0);
    // and the in-process run shipped nothing
    assert!(local.fabric_stats().is_none());
}

/// A request-level failure (unknown domain) answers with a clean typed
/// error and leaves the connection serving.
#[test]
fn unknown_domain_is_clean_error_and_connection_survives() {
    let shared = Arc::new(synthetic_store().unwrap());
    let addr =
        spawn_shared_node(native_be(), Arc::clone(&shared)).unwrap();
    let mut fabric =
        RemoteFabric::connect(&addr.to_string(), test_cfg()).unwrap();

    let q = trivial_q();
    let bad = trivial_plan("nope");
    fabric.submit(0, &[(&q, &bad)]).unwrap();
    let err = fabric.collect().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown domain"), "{msg}");

    // the fabric keeps serving valid requests (the errored connection
    // is dropped defensively; reconnect is transparent)
    let good = trivial_plan(SYNTH_DOMAIN);
    fabric.submit(0, &[(&q, &good)]).unwrap();
    let replies = fabric.collect().unwrap();
    assert_eq!(replies.len(), 1);
    assert_eq!(replies[0].parts.len(), 1);
}

/// A malformed plan (rows out of range) is rejected by validation, not
/// by a panic deep in the kernels.
#[test]
fn out_of_range_plan_is_rejected() {
    let shared = Arc::new(synthetic_store().unwrap());
    let addr =
        spawn_shared_node(native_be(), Arc::clone(&shared)).unwrap();
    let mut fabric =
        RemoteFabric::connect(&addr.to_string(), test_cfg()).unwrap();

    let mut plan = trivial_plan(SYNTH_DOMAIN);
    plan.calls.push(moska::plan::GemmCall {
        chunk_start: 9999,
        run_len: 1,
        rows: vec![0],
        k_base: 0,
        valid: 64,
        pos_override: None,
    });
    let q = trivial_q();
    fabric.submit(0, &[(&q, &plan)]).unwrap();
    let msg = format!("{:#}", fabric.collect().unwrap_err());
    assert!(msg.contains("out of range"), "{msg}");
}

/// The `Sync` handshake ships the node's full planner state: every
/// resident domain's router embeddings + chunk geometry, bit-identical
/// to the store the node loaded, plus the store digest.
#[test]
fn sync_ships_planner_state_matching_the_store() {
    let shared = Arc::new(synthetic_store().unwrap());
    let addr =
        spawn_shared_node(native_be(), Arc::clone(&shared)).unwrap();
    let mut fabric =
        RemoteFabric::connect(&addr.to_string(), test_cfg()).unwrap();
    let sync = fabric.sync().unwrap();
    assert_eq!(sync.chunk, SYNTH_CHUNK);
    assert_eq!(sync.digest, shared.content_digest());
    assert_eq!(sync.domains.len(), shared.domains.len());
    let view = moska::kvcache::shared_store::SharedStore::
        from_planner_states(sync.chunk, sync.domains).unwrap();
    assert_eq!(view.resident_bytes(), 0, "planner view must be K/V-less");
    for (name, dom) in &shared.domains {
        let v = view.domain(name).unwrap();
        assert_eq!(v.token_len(), dom.token_len());
        assert_eq!(v.chunk_bases, dom.chunk_bases);
        for l in 0..dom.layers.len() {
            assert_eq!(v.embeddings(l).as_f32(), dom.embeddings(l).as_f32(),
                       "embeddings for '{name}' layer {l} not bit-exact");
        }
    }
}

/// Mini server that serves exactly one ExecShared per connection then
/// drops it — the client must reconnect + resend transparently.
fn flaky_one_shot_server() -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut s) = stream else { continue };
            loop {
                match codec::read_frame(&mut s) {
                    Ok((WireMsg::Hello, _)) => {
                        let ack = WireMsg::HelloAck(HelloAck {
                            chunk: SYNTH_CHUNK,
                            domains: vec![SYNTH_DOMAIN.into()],
                            digest: 7,
                            kv_dtype: moska::tensor::KvDtype::F32,
                            server_now_ns: 0,
                        });
                        if s.write_all(&codec::frame_bytes(&ack)).is_err() {
                            break;
                        }
                    }
                    Ok((WireMsg::ExecShared(_), _)) => {
                        let reply = WireMsg::Partials {
                            parts: vec![Partials::identity(1, 4, 16)],
                            exec_ns: 1,
                            trace_id: 0,
                            spans: Vec::new(),
                        };
                        let _ = s.write_all(&codec::frame_bytes(&reply));
                        break; // drop the connection after one request
                    }
                    _ => break,
                }
            }
        }
    });
    addr
}

/// Dropped connections surface as retry + recovery, not as a hang or a
/// hard error.
#[test]
fn dropped_connection_retries_and_recovers() {
    let addr = flaky_one_shot_server();
    let mut fabric =
        RemoteFabric::connect(&addr.to_string(), test_cfg()).unwrap();

    let q = trivial_q();
    let plan = trivial_plan(SYNTH_DOMAIN);
    for round in 0..3 {
        fabric.submit(0, &[(&q, &plan)]).unwrap();
        let replies = fabric.collect().unwrap_or_else(|e| {
            panic!("round {round} failed: {e:#}")
        });
        assert_eq!(replies.len(), 1, "round {round}");
        assert_eq!(replies[0].parts.len(), 1, "round {round}");
    }
    let st = fabric.stats().unwrap();
    assert!(st.retries.load(std::sync::atomic::Ordering::Relaxed) >= 1,
            "rounds 2+ must have hit the reconnect path");
}

/// A codec-version mismatch answers with a clean Error frame (from the
/// real server) and a typed client-side error — never a hang.
#[test]
fn version_mismatch_is_clean_both_ways() {
    let shared = Arc::new(synthetic_store().unwrap());
    let addr =
        spawn_shared_node(native_be(), Arc::clone(&shared)).unwrap();

    // server side: send a frame stamped v+1; expect an Error frame back
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut frame = codec::frame_bytes(&WireMsg::Hello);
    frame[4..6].copy_from_slice(
        &(codec::CODEC_VERSION + 1).to_le_bytes(),
    );
    raw.write_all(&frame).unwrap();
    let (reply, _) = codec::read_frame(&mut raw).unwrap();
    match reply {
        WireMsg::Error(e) => {
            assert!(e.contains("version"), "{e}");
        }
        other => panic!("expected Error frame, got {other:?}"),
    }

    // client side: a foreign-version reply decodes to a typed error
    let bad = {
        let mut f = codec::frame_bytes(&WireMsg::Error("x".into()));
        f[4..6].copy_from_slice(&(codec::CODEC_VERSION + 7).to_le_bytes());
        f
    };
    let err =
        codec::read_frame(&mut std::io::Cursor::new(&bad)).unwrap_err();
    assert!(matches!(err, codec::CodecError::VersionMismatch { .. }),
            "{err}");
}

/// StepPlan frames — the whole-step IR — roundtrip through the wire
/// format (the future whole-step offload path has a pinned layout).
#[test]
fn step_plan_frame_roundtrips() {
    let msg = WireMsg::StepPlan(moska::plan::StepPlan {
        b: 2,
        pos: vec![10, 20],
        shared_groups: vec![trivial_plan(SYNTH_DOMAIN)],
        route_live: false,
        unique: vec![
            moska::plan::UniqueRowPlan { spans: vec![] },
            moska::plan::UniqueRowPlan {
                spans: vec![moska::plan::PageSpan {
                    page_start: 0,
                    pages: 2,
                    k_base: 512,
                    valid: 100,
                }],
            },
        ],
        unique_work: 12345,
        max_batch: 32,
        position_independent: false,
    });
    let bytes = codec::frame_bytes(&msg);
    let (back, n) =
        codec::read_frame(&mut std::io::Cursor::new(&bytes)).unwrap();
    assert_eq!(n, bytes.len());
    assert_eq!(back, msg);
}
