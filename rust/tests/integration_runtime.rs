//! Runtime integration: AOT artifacts (PJRT) ⇄ python goldens ⇄ native ops.
//!
//! Requires `make artifacts`; every test skips cleanly when the artifacts
//! tree is absent so `cargo test` stays green on a fresh checkout.

use moska::runtime::native::Partials;
use moska::runtime::{artifact, Backend, NativeBackend, RuntimeService, XlaBackend};
use moska::tensor::Tensor;
use moska::util::json::Json;
use moska::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = artifact::default_artifacts_dir();
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn xla_backend(dir: &str) -> (RuntimeService, XlaBackend) {
    let svc = RuntimeService::spawn(dir).expect("runtime service");
    let be = XlaBackend::new(svc.handle());
    (svc, be)
}

/// JSON goldens store -inf as -3.0e38 (no inf literal in JSON).
fn decode_golden_f32(v: &Json) -> Vec<f32> {
    v.as_f32_vec()
        .unwrap()
        .into_iter()
        .map(|x| if x <= -3.0e38 { f32::NEG_INFINITY } else { x })
        .collect()
}

fn rand_t(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let mut data = vec![0f32; shape.iter().product()];
    rng.fill_normal_f32(&mut data);
    Tensor::f32(shape, data)
}

#[test]
fn chunk_attn_artifact_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = Json::read_file(&format!("{dir}/golden/kernels.json")).unwrap();
    let g = g.get("chunk_attn").unwrap();

    let q = Tensor::f32(&[4, 4, 16], decode_golden_f32(g.get("q").unwrap()));
    let k = Tensor::f32(&[64, 2, 16], decode_golden_f32(g.get("k").unwrap()));
    let v = Tensor::f32(&[64, 2, 16], decode_golden_f32(g.get("v").unwrap()));
    let q_pos = g.get("q_pos").unwrap().as_i32_vec().unwrap();
    let k_base = g.get("k_base").unwrap().as_i64().unwrap() as i32;
    let valid = g.get("valid").unwrap().as_i64().unwrap() as i32;

    let want_o = Tensor::f32(&[4, 4, 16], decode_golden_f32(g.get("o").unwrap()));
    let want_m = Tensor::f32(&[4, 4], decode_golden_f32(g.get("m").unwrap()));
    let want_l = Tensor::f32(&[4, 4], decode_golden_f32(g.get("l").unwrap()));

    let (_svc, be) = xla_backend(&dir);
    let got = be.chunk_attn(&q, &k, &v, &q_pos, k_base, valid).unwrap();
    assert!(got.o.max_abs_diff(&want_o) < 1e-4, "o diff {}", got.o.max_abs_diff(&want_o));
    assert!(got.m.max_abs_diff(&want_m) < 1e-4);
    assert!(got.l.max_abs_diff(&want_l) < 1e-4);

    // and the native oracle agrees with both
    let nat = NativeBackend::tiny();
    let got_n = nat.chunk_attn(&q, &k, &v, &q_pos, k_base, valid).unwrap();
    assert!(got_n.o.max_abs_diff(&want_o) < 1e-4);
    assert!(got_n.m.max_abs_diff(&want_m) < 1e-4);
    assert!(got_n.l.max_abs_diff(&want_l) < 1e-4);
}

#[test]
fn router_artifact_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = Json::read_file(&format!("{dir}/golden/kernels.json")).unwrap();
    let g = g.get("router").unwrap();
    let q = Tensor::f32(&[4, 4, 16], decode_golden_f32(g.get("q").unwrap()));
    let embs = Tensor::f32(&[16, 2, 16], decode_golden_f32(g.get("embs").unwrap()));
    let want = Tensor::f32(&[4, 16], decode_golden_f32(g.get("scores").unwrap()));

    let (_svc, be) = xla_backend(&dir);
    let got = be.router(&q, &embs).unwrap();
    assert!(got.max_abs_diff(&want) < 1e-4, "{}", got.max_abs_diff(&want));

    let nat = NativeBackend::tiny();
    let got_n = nat.router(&q, &embs).unwrap();
    assert!(got_n.max_abs_diff(&want) < 1e-4);
}

#[test]
fn xla_and_native_agree_on_random_inputs_all_buckets() {
    let Some(dir) = artifacts_dir() else { return };
    let (_svc, be) = xla_backend(&dir);
    let nat = NativeBackend::tiny();
    let mut rng = Rng::new(42);
    let chunk = be.chunk_size();

    for &b in &[1usize, 3, 5, 8, 17, 32] {
        let q = rand_t(&mut rng, &[b, 4, 16]);
        let k = rand_t(&mut rng, &[chunk, 2, 16]);
        let v = rand_t(&mut rng, &[chunk, 2, 16]);
        let q_pos: Vec<i32> = (0..b)
            .map(|i| if i % 5 == 4 { -1 } else { (rng.below(200)) as i32 })
            .collect();
        let a = be.chunk_attn(&q, &k, &v, &q_pos, 30, chunk as i32).unwrap();
        let n = nat.chunk_attn(&q, &k, &v, &q_pos, 30, chunk as i32).unwrap();
        assert!(a.o.max_abs_diff(&n.o) < 1e-4, "b={b} o {}", a.o.max_abs_diff(&n.o));
        assert!(a.m.max_abs_diff(&n.m) < 1e-4, "b={b}");
        assert!(a.l.max_abs_diff(&n.l) < 1e-4, "b={b}");
    }
}

#[test]
fn qkv_post_lmhead_agree_with_native() {
    let Some(dir) = artifacts_dir() else { return };
    let (svc, be) = xla_backend(&dir);
    let nat = NativeBackend::tiny();
    let man = svc.handle().manifest;
    let weights = moska::util::bin::Store::load(
        man.weights_path().to_str().unwrap(),
    )
    .unwrap();
    let mut rng = Rng::new(7);
    let cfg = be.model().clone();

    for &b in &[1usize, 2, 6, 16] {
        let tokens = Tensor::i32(&[b], (0..b).map(|i| (i * 37 % 256) as i32).collect());
        let emb = weights.get("embed").unwrap();
        let xa = be.embed(&tokens, emb).unwrap();
        let xn = nat.embed(&tokens, emb).unwrap();
        assert!(xa.max_abs_diff(&xn) < 1e-5, "embed b={b}");

        let pos: Vec<i32> = (0..b as i32).map(|i| i * 3).collect();
        let (qa, ka, va) = be
            .qkv(&xa, weights.get("layer0.attn_norm").unwrap(),
                 weights.get("layer0.wq").unwrap(),
                 weights.get("layer0.wk").unwrap(),
                 weights.get("layer0.wv").unwrap(), &pos)
            .unwrap();
        let (qn, kn, vn) = nat
            .qkv(&xn, weights.get("layer0.attn_norm").unwrap(),
                 weights.get("layer0.wq").unwrap(),
                 weights.get("layer0.wk").unwrap(),
                 weights.get("layer0.wv").unwrap(), &pos)
            .unwrap();
        assert!(qa.max_abs_diff(&qn) < 1e-4, "q b={b} {}", qa.max_abs_diff(&qn));
        assert!(ka.max_abs_diff(&kn) < 1e-4);
        assert!(va.max_abs_diff(&vn) < 1e-4);

        let attn_o = rand_t(&mut rng, &[b, cfg.n_heads, cfg.head_dim]);
        let x = rand_t(&mut rng, &[b, cfg.d_model]);
        let pa = be
            .post(&attn_o, &x, weights.get("layer0.wo").unwrap(),
                  weights.get("layer0.ffn_norm").unwrap(),
                  weights.get("layer0.w1").unwrap(),
                  weights.get("layer0.w3").unwrap(),
                  weights.get("layer0.w2").unwrap())
            .unwrap();
        let pn = nat
            .post(&attn_o, &x, weights.get("layer0.wo").unwrap(),
                  weights.get("layer0.ffn_norm").unwrap(),
                  weights.get("layer0.w1").unwrap(),
                  weights.get("layer0.w3").unwrap(),
                  weights.get("layer0.w2").unwrap())
            .unwrap();
        assert!(pa.max_abs_diff(&pn) < 1e-3, "post b={b} {}", pa.max_abs_diff(&pn));

        let la = be
            .lm_head(&x, weights.get("final_norm").unwrap(),
                     weights.get("lm_head").unwrap())
            .unwrap();
        let ln = nat
            .lm_head(&x, weights.get("final_norm").unwrap(),
                     weights.get("lm_head").unwrap())
            .unwrap();
        assert!(la.max_abs_diff(&ln) < 1e-3, "lm_head b={b}");
    }
}

#[test]
fn merge2_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let (_svc, be) = xla_backend(&dir);
    let nat = NativeBackend::tiny();
    let mut rng = Rng::new(3);
    let chunk = be.chunk_size();
    let q = rand_t(&mut rng, &[8, 4, 16]);
    let k1 = rand_t(&mut rng, &[chunk, 2, 16]);
    let v1 = rand_t(&mut rng, &[chunk, 2, 16]);
    let k2 = rand_t(&mut rng, &[chunk, 2, 16]);
    let v2 = rand_t(&mut rng, &[chunk, 2, 16]);
    let q_pos: Vec<i32> = vec![500; 8];
    let p1 = nat.chunk_attn(&q, &k1, &v1, &q_pos, 0, chunk as i32).unwrap();
    let p2 = nat.chunk_attn(&q, &k2, &v2, &q_pos, chunk as i32, chunk as i32).unwrap();
    let ma = be.merge2(&p1, &p2).unwrap();
    let mn = nat.merge2(&p1, &p2).unwrap();
    assert!(ma.o.max_abs_diff(&mn.o) < 1e-4);
    assert!(ma.m.max_abs_diff(&mn.m) < 1e-4);
    assert!(ma.l.max_abs_diff(&mn.l) < 1e-4);
}

#[test]
fn merge_identity_through_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let (_svc, be) = xla_backend(&dir);
    let nat = NativeBackend::tiny();
    let mut rng = Rng::new(4);
    let chunk = be.chunk_size();
    let q = rand_t(&mut rng, &[2, 4, 16]);
    let k = rand_t(&mut rng, &[chunk, 2, 16]);
    let v = rand_t(&mut rng, &[chunk, 2, 16]);
    let p = nat.chunk_attn(&q, &k, &v, &[100, 300], 0, chunk as i32).unwrap();
    let id = Partials::identity(2, 4, 16);
    let merged = be.merge2(&p, &id).unwrap();
    assert!(merged.o.max_abs_diff(&p.o) < 1e-5);
    assert!(merged.l.max_abs_diff(&p.l) < 1e-5);
}

#[test]
fn manifest_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::spawn(&dir).unwrap();
    let h = svc.handle();
    // wrong rank
    let r = h.execute("embed_b1", vec![Tensor::zeros_i32(&[2])]);
    assert!(r.is_err());
    // wrong dtype
    let man = &h.manifest;
    let emb_shape = vec![man.model.vocab, man.model.d_model];
    let r = h.execute(
        "embed_b1",
        vec![Tensor::zeros_f32(&[1]), Tensor::zeros_f32(&emb_shape)],
    );
    assert!(r.is_err());
    // unknown artifact
    let r = h.execute("nope_b1", vec![]);
    assert!(r.is_err());
}

#[test]
fn handle_is_shareable_across_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = RuntimeService::spawn(&dir).unwrap();
    let h = svc.handle();
    let man = h.manifest.clone();
    let emb = Tensor::zeros_f32(&[man.model.vocab, man.model.d_model]);
    let mut joins = Vec::new();
    for t in 0..4 {
        let h = h.clone();
        let emb = emb.clone();
        let d_model = man.model.d_model;
        joins.push(std::thread::spawn(move || {
            for i in 0..5 {
                let tokens = Tensor::i32(&[1], vec![((t * 7 + i) % 256) as i32]);
                let out = h.execute("embed_b1", vec![tokens, emb.clone()]).unwrap();
                assert_eq!(out[0].shape(), &[1, d_model]);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}
