//! Engine integration: the "all layers compose" proof.
//!
//! Replays the golden decode traces produced by the pure-JAX reference
//! (`python/compile/aot.py write_goldens`) through the full rust engine —
//! router (dense) → Shared-KV batcher → PJRT Pallas artifacts → LSE merge
//! → sampling — and asserts the logits agree to ≤ 1e-3 and the greedy
//! token choices match exactly. Also covers batched decode consistency,
//! sparse-routing behaviour, admission control, and page accounting.

use moska::config::ServingConfig;
use moska::engine::{build_engine, Engine};
use moska::model::sampling::Sampler;
use moska::runtime::artifact::default_artifacts_dir;
use moska::util::json::Json;

fn artifacts_dir() -> Option<String> {
    let dir = default_artifacts_dir();
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn golden(dir: &str, name: &str) -> Json {
    Json::read_file(&format!("{dir}/golden/{name}")).unwrap()
}

fn dense_engine(dir: &str, backend: &str)
    -> (Engine, Option<moska::runtime::RuntimeService>) {
    let cfg = ServingConfig { top_k: None, ..Default::default() };
    build_engine(dir, backend, cfg).unwrap()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Golden decode without shared context, on both backends.
fn check_prompt_golden(backend: &str) {
    let Some(dir) = artifacts_dir() else { return };
    let g = golden(&dir, "decode_prompt.json");
    let prompt = g.get("prompt").unwrap().as_i32_vec().unwrap();
    let want_tokens = g.get("tokens").unwrap().as_i32_vec().unwrap();
    let want_logits: Vec<Vec<f32>> = g
        .get("logits").unwrap().as_arr().unwrap()
        .iter().map(|r| r.as_f32_vec().unwrap()).collect();

    let (mut eng, _svc) = dense_engine(&dir, backend);
    eng.capture_logits = true;
    let id = eng
        .submit(None, prompt, want_tokens.len(), Sampler::Greedy)
        .unwrap();
    let results = eng.run_to_completion().unwrap();
    assert_eq!(results.len(), 1);
    let r = &results[0];
    assert_eq!(r.id, id);
    assert_eq!(r.tokens, want_tokens, "greedy tokens diverged ({backend})");
    assert_eq!(r.logits_trace.len(), want_logits.len());
    for (step, (got, want)) in
        r.logits_trace.iter().zip(&want_logits).enumerate()
    {
        let d = max_abs_diff(got, want);
        assert!(d < 1e-3, "step {step} logits diff {d} ({backend})");
    }
}

#[test]
fn golden_decode_prompt_xla() {
    check_prompt_golden("xla");
}

#[test]
fn golden_decode_prompt_native() {
    check_prompt_golden("native");
}

/// Golden decode over the 'code' shared domain (1024 shared tokens):
/// engine serves from the precomputed shared KV store; reference did a
/// monolithic prefill. Dense routing → must agree.
fn check_shared_golden(backend: &str) {
    let Some(dir) = artifacts_dir() else { return };
    let g = golden(&dir, "decode_shared.json");
    let domain = g.get("domain").unwrap().as_str().unwrap().to_string();
    let prompt = g.get("prompt").unwrap().as_i32_vec().unwrap();
    let want_tokens = g.get("tokens").unwrap().as_i32_vec().unwrap();
    let want_logits: Vec<Vec<f32>> = g
        .get("logits").unwrap().as_arr().unwrap()
        .iter().map(|r| r.as_f32_vec().unwrap()).collect();

    let (mut eng, _svc) = dense_engine(&dir, backend);
    eng.capture_logits = true;
    eng.submit(Some(&domain), prompt, want_tokens.len(), Sampler::Greedy)
        .unwrap();
    let results = eng.run_to_completion().unwrap();
    let r = &results[0];
    assert_eq!(r.tokens, want_tokens,
               "greedy tokens over shared domain diverged ({backend})");
    for (step, (got, want)) in
        r.logits_trace.iter().zip(&want_logits).enumerate()
    {
        let d = max_abs_diff(got, want);
        assert!(d < 1e-3, "step {step} logits diff {d} ({backend})");
    }
}

#[test]
fn golden_decode_shared_domain_xla() {
    check_shared_golden("xla");
}

#[test]
fn golden_decode_shared_domain_native() {
    check_shared_golden("native");
}

/// A request decoded alone must produce the same tokens as the same
/// request decoded inside a 6-way batch (batching must not change math).
#[test]
fn batched_decode_matches_solo() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt: Vec<i32> = vec![10, 20, 30, 40, 50, 60, 70];
    let steps = 6;

    let (mut solo, _s1) = dense_engine(&dir, "xla");
    solo.submit(Some("legal"), prompt.clone(), steps, Sampler::Greedy)
        .unwrap();
    let solo_tokens = solo.run_to_completion().unwrap()[0].tokens.clone();

    let (mut batch, _s2) = dense_engine(&dir, "xla");
    // surround the probe request with different traffic
    for i in 0..3i32 {
        let p: Vec<i32> = (0..9).map(|j| (i * 31 + j * 7) % 256).collect();
        batch.submit(Some("legal"), p, steps, Sampler::Greedy).unwrap();
    }
    let probe = batch
        .submit(Some("legal"), prompt, steps, Sampler::Greedy)
        .unwrap();
    for i in 0..2i32 {
        let p: Vec<i32> = (0..11).map(|j| (i * 13 + j * 5 + 3) % 256).collect();
        batch.submit(Some("medical"), p, steps, Sampler::Greedy).unwrap();
    }
    let results = batch.run_to_completion().unwrap();
    let probe_tokens = &results.iter().find(|r| r.id == probe).unwrap().tokens;
    assert_eq!(probe_tokens, &solo_tokens);
    // batching actually happened: shared GEMM factor must exceed 1
    assert!(batch.batching_factor() > 1.5,
            "batching factor {}", batch.batching_factor());
}

/// Sparse routing (top-k) runs, prunes work, and stays plausible.
#[test]
fn sparse_routing_prunes_and_decodes() {
    let Some(dir) = artifacts_dir() else { return };
    let prompt: Vec<i32> = (0..12).map(|i| (i * 17 + 5) % 256).collect();

    let cfg = ServingConfig { top_k: Some(4), ..Default::default() };
    let (mut eng, _svc) = build_engine(&dir, "xla", cfg).unwrap();
    eng.submit(Some("code"), prompt, 4, Sampler::Greedy).unwrap();
    let results = eng.run_to_completion().unwrap();
    assert_eq!(results[0].tokens.len(), 4);
    // code domain has 16 chunks; top-4 → 75% sparsity
    let s = eng.router.stats.sparsity();
    assert!((s - 0.75).abs() < 0.01, "sparsity {s}");
}

/// Admission control rejects what cannot fit and pages never leak.
#[test]
fn admission_and_page_accounting() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServingConfig::default();
    let (mut eng, _svc) = build_engine(&dir, "native", cfg).unwrap();

    // gigantic request: 4096-page pool can't hold 200k tokens × 2 layers
    let huge = vec![1i32; 64];
    assert!(eng.submit(None, huge, 200_000, Sampler::Greedy).is_err());

    // normal requests: pages must return to zero after completion
    for i in 0..4i32 {
        let p: Vec<i32> = (0..10).map(|j| (i * 3 + j) % 256).collect();
        eng.submit(Some("code"), p, 5, Sampler::Greedy).unwrap();
    }
    let results = eng.run_to_completion().unwrap();
    assert_eq!(results.len(), 4);
    assert_eq!(eng.pool.allocated(), 0, "pages leaked");
    assert!(eng.pool.peak_allocated() > 0);
}

/// Position-independent (Universal MoSKA) mode runs end-to-end; it is an
/// approximation, so we only require sane outputs and full pipeline
/// execution, not golden equality.
#[test]
fn position_independent_mode_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServingConfig {
        position_independent: true,
        top_k: Some(4),
        ..Default::default()
    };
    let (mut eng, _svc) = build_engine(&dir, "native", cfg).unwrap();
    let prompt: Vec<i32> = (0..8).map(|i| (i * 29 + 1) % 256).collect();
    eng.submit(Some("legal"), prompt, 4, Sampler::Greedy).unwrap();
    let results = eng.run_to_completion().unwrap();
    assert_eq!(results[0].tokens.len(), 4);
    for &t in &results[0].tokens {
        assert!((0..256).contains(&t));
    }
}

/// Continuous batching: more requests than max_batch complete correctly.
#[test]
fn continuous_batching_overflow() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServingConfig { max_batch: 2, ..Default::default() };
    let (mut eng, _svc) = build_engine(&dir, "native", cfg).unwrap();
    let mut expected = Vec::new();
    for i in 0..5i32 {
        let p: Vec<i32> = (0..8).map(|j| (i * 41 + j * 3) % 256).collect();
        // solo reference for each
        let (mut solo, _s) = dense_engine(&dir, "native");
        solo.submit(Some("code"), p.clone(), 3, Sampler::Greedy).unwrap();
        expected.push(solo.run_to_completion().unwrap()[0].tokens.clone());
        eng.submit(Some("code"), p, 3, Sampler::Greedy).unwrap();
    }
    let mut results = eng.run_to_completion().unwrap();
    results.sort_by_key(|r| r.id);
    for (r, want) in results.iter().zip(&expected) {
        assert_eq!(&r.tokens, want);
    }
}
