//! Serving-loop HTTP surface tests: SSE streaming framing, streamed
//! output byte-identical to the non-streaming reply, mid-stream client
//! disconnect retiring the request and releasing its KV pages, and the
//! traffic generator's seed-determinism — all against a synthetic
//! engine over loopback, no artifacts, no sleeps on the happy path.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use moska::config::{ModelConfig, ServingConfig};
use moska::engine::Engine;
use moska::kvcache::SharedStore;
use moska::model::Weights;
use moska::runtime::NativeBackend;
use moska::util::json::Json;
use moska::workload::loadgen::{run_inprocess, scenario_items, Scenario};
use moska::workload::trace_to_json;

const CHUNK: usize = 64;

fn synthetic_engine() -> Engine {
    let model = ModelConfig::tiny();
    let cfg = ServingConfig {
        top_k: None,
        max_batch: 8,
        exec_threads: 1,
        ..Default::default()
    };
    let be = NativeBackend::with_threads(model.clone(), CHUNK, 1);
    let weights = Weights::synthetic(model, 0x0B5E);
    let mut eng = Engine::new(
        Box::new(be), weights, SharedStore::empty(CHUNK), cfg, 1024,
    );
    let tokens: Vec<i32> =
        (0..2 * CHUNK).map(|i| (i % 100) as i32).collect();
    eng.register_domain("bench", &tokens).expect("register domain");
    eng
}

fn spawn_server() -> SocketAddr {
    let engine = synthetic_engine();
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = moska::server::serve_on(
            "127.0.0.1:0".parse().unwrap(), engine, Some(tx),
        );
    });
    rx.recv().expect("server ready")
}

/// One HTTP exchange; returns (header block, body).
fn http(addr: SocketAddr, req: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).expect("send");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("read");
    match resp.split_once("\r\n\r\n") {
        Some((h, b)) => (h.to_string(), b.to_string()),
        None => (resp, String::new()),
    }
}

fn post_generate(addr: SocketAddr, body: &str) -> (String, String) {
    http(addr, &format!(
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(), body,
    ))
}

fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    http(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

/// Poll an endpoint until `ok(body)` or a deadline (the engine loop
/// refreshes its stats snapshot between decode steps).
fn poll_get(addr: SocketAddr, path: &str,
            ok: impl Fn(&str) -> bool) -> (String, String) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (head, body) = http_get(addr, path);
        if ok(&body) {
            return (head, body);
        }
        assert!(Instant::now() < deadline,
                "{path} never reached the expected state; last: {body}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Split an SSE body into its token frames and the terminal `done`
/// payload, rejecting error frames and anything unrecognized.
fn parse_sse(body: &str) -> (Vec<i32>, Json) {
    let mut tokens = Vec::new();
    let mut done = None;
    for frame in body.split("\n\n").filter(|f| !f.is_empty()) {
        if let Some(rest) = frame.strip_prefix("data: ") {
            let j = Json::parse(rest).expect("token frame JSON");
            let t = j.get("token").expect("token field")
                .as_f64().expect("token number") as i32;
            assert!(done.is_none(), "token frame after done: {frame}");
            tokens.push(t);
        } else if let Some(rest) = frame.strip_prefix("event: done\ndata: ")
        {
            assert!(done.is_none(), "two done frames");
            done = Some(Json::parse(rest).expect("done frame JSON"));
        } else {
            panic!("unexpected SSE frame: {frame:?}");
        }
    }
    (tokens, done.expect("stream ended without a done frame"))
}

/// SSE framing and the streaming bit-identity contract: every sampled
/// token arrives as its own `data: {"token":N}` frame, the terminal
/// `event: done` payload carries the same body a non-streaming request
/// returns, and the streamed token sequence is byte-identical to the
/// non-streaming `tokens` array for the same greedy request.
#[test]
fn sse_stream_byte_identical_to_nonstream() {
    let addr = spawn_server();
    let req = |stream: bool| format!(
        r#"{{"prompt": "abcdef", "domain": "bench", "max_tokens": 6, "stream": {stream}}}"#,
    );

    let (head, body) = post_generate(addr, &req(true));
    assert!(head.starts_with("HTTP/1.1 200"), "{head}\n{body}");
    assert!(head.contains("text/event-stream"), "{head}");
    let (streamed, done) = parse_sse(&body);
    assert_eq!(streamed.len(), 6, "one frame per generated token");

    let (head, plain) = post_generate(addr, &req(false));
    assert!(head.starts_with("HTTP/1.1 200"), "{head}\n{plain}");
    assert!(head.contains("application/json"), "{head}");
    let plain = Json::parse(&plain).expect("non-streaming reply JSON");

    // greedy decode is deterministic, so the two requests generate the
    // same tokens; compare the serialized fields byte-for-byte
    assert_eq!(done.get("tokens").unwrap().to_string(),
               plain.get("tokens").unwrap().to_string(),
               "done frame vs non-streaming tokens");
    assert_eq!(done.get("text").unwrap().to_string(),
               plain.get("text").unwrap().to_string(),
               "done frame vs non-streaming text");
    assert_eq!(streamed,
               plain.get("tokens").unwrap().as_i32_vec().unwrap(),
               "incremental frames vs final token array");
}

/// Malformed streaming requests fail before the stream commits: the
/// client gets a plain HTTP error, not a broken SSE body.
#[test]
fn sse_request_errors_are_http_errors() {
    let addr = spawn_server();
    let body = r#"{"prompt": "ab", "domain": "nope", "max_tokens": 2, "stream": true}"#;
    let (head, body) = post_generate(addr, body);
    assert!(head.starts_with("HTTP/1.1 400"), "{head}\n{body}");
    assert!(!head.contains("text/event-stream"), "{head}");
}

/// A client that walks away mid-stream must not leak: the engine
/// notices the dead connection, cancels the request, and releases its
/// KV pages — observed through /stats draining to zero.
#[test]
fn sse_disconnect_retires_request_and_releases_pages() {
    let addr = spawn_server();
    // long enough that generation cannot finish before we disconnect
    let body = r#"{"prompt": "abcd", "domain": "bench", "max_tokens": 20000, "stream": true}"#;
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(format!(
            "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
            body.len(), body,
        ).as_bytes()).expect("send");
        // read until a few token frames arrived, then hang up
        let mut seen = String::new();
        let mut buf = [0u8; 4096];
        let deadline = Instant::now() + Duration::from_secs(10);
        while seen.matches("data: {\"token\"").count() < 3 {
            assert!(Instant::now() < deadline,
                    "no token frames before deadline; got: {seen}");
            let n = s.read(&mut buf).expect("read frames");
            assert!(n > 0, "stream closed early: {seen}");
            seen.push_str(&String::from_utf8_lossy(&buf[..n]));
        }
        assert!(seen.contains("text/event-stream"), "{seen}");
    } // socket dropped here — mid-stream disconnect

    // the engine cancels the request on the failed frame send and its
    // pages return to the pool
    let drained = |body: &str| {
        let Ok(j) = Json::parse(body) else { return false };
        let num = |k: &str| {
            j.get(k).ok().and_then(|v| v.as_f64().ok()).unwrap_or(-1.0)
        };
        num("live") == 0.0 && num("queued") == 0.0
            && num("kv_pages_allocated") == 0.0
    };
    let (_, stats) = poll_get(addr, "/stats", drained);
    // and nothing was recorded as a completion
    let j = Json::parse(&stats).unwrap();
    assert_eq!(
        j.get("lifecycle").unwrap().get("completed").unwrap()
            .as_f64().unwrap(),
        0.0,
        "cancelled request must not count as completed",
    );
}

/// Traffic generator determinism (the BENCH_serving.json contract):
/// the same (scenario, n, seed) triple yields a byte-identical WorkItem
/// trace and identical count/mix report columns; a different seed
/// yields a different trace.
#[test]
fn loadgen_same_seed_same_trace_and_report_columns() {
    for sc in [Scenario::RagShared, Scenario::Mixed] {
        let a = scenario_items(sc, 24, 42);
        let b = scenario_items(sc, 24, 42);
        assert_eq!(trace_to_json(&a).to_string(),
                   trace_to_json(&b).to_string(),
                   "{sc:?}: trace JSON not seed-deterministic");
        let ra = run_inprocess(sc, &a, 42).unwrap().to_json();
        let rb = run_inprocess(sc, &b, 42).unwrap().to_json();
        for col in ["scenario", "mode", "seed", "requests", "errors",
                    "streamed_tokens", "generated_tokens", "mix"] {
            assert_eq!(ra.get(col).unwrap().to_string(),
                       rb.get(col).unwrap().to_string(),
                       "{sc:?}: column {col} differs between runs");
        }
        assert_eq!(
            ra.get("errors").unwrap().as_f64().unwrap(), 0.0,
            "{sc:?}: scenario items must all pass admission",
        );
        let c = scenario_items(sc, 24, 43);
        assert_ne!(trace_to_json(&a).to_string(),
                   trace_to_json(&c).to_string(),
                   "{sc:?}: seed does not influence the trace");
    }
}
