//! Feature integration: runtime domain registration (rust prefill vs
//! python-precomputed stores), multi-turn sessions (prefix reuse), and
//! composable contexts (Universal MoSKA).

use moska::config::ServingConfig;
use moska::engine::{build_engine, Engine};
use moska::model::sampling::Sampler;
use moska::runtime::artifact::default_artifacts_dir;

fn artifacts_dir() -> Option<String> {
    let dir = default_artifacts_dir();
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn dense_engine(dir: &str, backend: &str)
    -> (Engine, Option<moska::runtime::RuntimeService>) {
    build_engine(dir, backend,
                 ServingConfig { top_k: None, ..Default::default() })
        .unwrap()
}

/// Rust online prefill == python build-time prefill, chunk for chunk.
/// This cross-validates the whole prefill path (embed/qkv/RoPE/attention/
/// FFN through the artifacts) against the JAX reference numerics.
#[test]
fn registered_domain_matches_precomputed() {
    let Some(dir) = artifacts_dir() else { return };
    let (mut eng, _svc) = dense_engine(&dir, "xla");
    // re-prefill the 'code' domain's corpus under a new name
    let tokens = eng.shared.domain("code").unwrap().tokens.clone();
    eng.register_domain("code2", &tokens).unwrap();

    let orig = eng.shared.domain("code").unwrap();
    let redo = eng.shared.domain("code2").unwrap();
    assert_eq!(orig.n_chunks, redo.n_chunks);
    for l in 0..orig.layers.len() {
        for c in 0..orig.n_chunks {
            let (k1, v1) = orig.chunk_kv(l, c);
            let (k2, v2) = redo.chunk_kv(l, c);
            let kd = k1.max_abs_diff(k2);
            let vd = v1.max_abs_diff(v2);
            assert!(kd < 1e-3, "layer {l} chunk {c} K diff {kd}");
            assert!(vd < 1e-3, "layer {l} chunk {c} V diff {vd}");
        }
        let ed = orig.embeddings(l).max_abs_diff(redo.embeddings(l));
        assert!(ed < 1e-3, "layer {l} embeddings diff {ed}");
    }
    // Note: rust-prefilled K/V is numerically close but not bit-identical
    // to the python store (fp reassociation), so content-hash dedup can't
    // trigger across the two pipelines. Registering the same corpus AGAIN
    // through rust is deterministic → every chunk dedups.
    let n_chunks = orig.n_chunks as u64;
    let hits_before = eng.shared.registry.dedup_hits;
    eng.register_domain("code3", &tokens).unwrap();
    assert!(
        eng.shared.registry.dedup_hits - hits_before >= n_chunks,
        "dedup hits {}", eng.shared.registry.dedup_hits
    );

    // and serving from the re-registered domain gives identical tokens
    let prompt: Vec<i32> = (0..9).map(|i| (i * 31 + 2) % 256).collect();
    eng.capture_logits = false;
    let a = eng.submit(Some("code"), prompt.clone(), 4, Sampler::Greedy)
        .unwrap();
    let b = eng.submit(Some("code2"), prompt, 4, Sampler::Greedy).unwrap();
    let results = eng.run_to_completion().unwrap();
    let ta = &results.iter().find(|r| r.id == a).unwrap().tokens;
    let tb = &results.iter().find(|r| r.id == b).unwrap().tokens;
    assert_eq!(ta, tb);
}

#[test]
fn register_domain_validates_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let (mut eng, _svc) = dense_engine(&dir, "native");
    assert!(eng.register_domain("bad", &[1, 2, 3]).is_err()); // not ×chunk
    assert!(eng.register_domain("bad", &[]).is_err());
    let chunk = eng.backend.chunk_size();
    assert!(eng.register_domain("legal", &vec![0; chunk]).is_err()); // dup
    // valid registration works and is immediately servable
    eng.register_domain("mini", &vec![7; chunk]).unwrap();
    eng.submit(Some("mini"), vec![1, 2, 3], 2, Sampler::Greedy).unwrap();
    let r = eng.run_to_completion().unwrap();
    assert_eq!(r[0].tokens.len(), 2);
    assert_eq!(eng.pool.allocated(), 0, "prefill pages leaked");
}

/// Two session turns == one fresh request over the concatenated history.
#[test]
fn session_matches_fresh_request() {
    let Some(dir) = artifacts_dir() else { return };
    let (mut eng, _svc) = dense_engine(&dir, "xla");

    let p1: Vec<i32> = vec![11, 22, 33, 44, 55, 66];
    let p2: Vec<i32> = vec![77, 88, 99];
    let (n1, n2) = (3usize, 4usize);

    // conversation: turn 1 then turn 2
    let sid = eng.open_session(Some("code")).unwrap();
    eng.submit_turn(sid, p1.clone(), n1, Sampler::Greedy).unwrap();
    let gen1 = eng.run_to_completion().unwrap().pop().unwrap().tokens;
    assert_eq!(gen1.len(), n1);
    eng.submit_turn(sid, p2.clone(), n2, Sampler::Greedy).unwrap();
    let gen2 = eng.run_to_completion().unwrap().pop().unwrap().tokens;
    assert_eq!(gen2.len(), n2);
    let sess = eng.session(sid).unwrap();
    assert_eq!(sess.turns, 2);

    // fresh request: prompt = p1 ++ gen1 ++ p2  (same visible history)
    let mut full = p1;
    full.extend_from_slice(&gen1);
    full.extend_from_slice(&p2);
    let (mut fresh, _svc2) = dense_engine(&dir, "xla");
    fresh.submit(Some("code"), full, n2, Sampler::Greedy).unwrap();
    let want = fresh.run_to_completion().unwrap().pop().unwrap().tokens;
    assert_eq!(gen2, want, "session turn-2 diverged from fresh request");

    // closing releases the pages
    let before = eng.pool.allocated();
    assert!(before > 0);
    eng.close_session(sid).unwrap();
    assert_eq!(eng.pool.allocated(), 0);
}

#[test]
fn session_busy_and_unknown_errors() {
    let Some(dir) = artifacts_dir() else { return };
    let (mut eng, _svc) = dense_engine(&dir, "native");
    assert!(eng.submit_turn(999, vec![1], 1, Sampler::Greedy).is_err());
    let sid = eng.open_session(None).unwrap();
    eng.submit_turn(sid, vec![1, 2], 2, Sampler::Greedy).unwrap();
    // turn in flight → busy
    assert!(eng.submit_turn(sid, vec![3], 1, Sampler::Greedy).is_err());
    assert!(eng.close_session(sid).is_err());
    eng.run_to_completion().unwrap();
    // now idle again
    eng.submit_turn(sid, vec![3], 1, Sampler::Greedy).unwrap();
    eng.run_to_completion().unwrap();
    eng.close_session(sid).unwrap();
}

/// Position-preserving composition of a domain's own chunks (in order)
/// must serve *identical* results to the native domain — LSE merging is
/// order/partition-invariant.
#[test]
fn full_composition_equals_native_domain() {
    let Some(dir) = artifacts_dir() else { return };
    let (mut eng, _svc) = dense_engine(&dir, "xla");
    let nc = eng.shared.domain("code").unwrap().n_chunks;
    eng.register_composed("code_composed", &format!("code:0-{}", nc - 1))
        .unwrap();

    let prompt: Vec<i32> = (0..8).map(|i| (i * 13 + 5) % 256).collect();
    let a = eng.submit(Some("code"), prompt.clone(), 4, Sampler::Greedy)
        .unwrap();
    let b = eng
        .submit(Some("code_composed"), prompt, 4, Sampler::Greedy)
        .unwrap();
    let results = eng.run_to_completion().unwrap();
    let ta = &results.iter().find(|r| r.id == a).unwrap().tokens;
    let tb = &results.iter().find(|r| r.id == b).unwrap().tokens;
    assert_eq!(ta, tb, "composed(all chunks) != native domain");
}

/// Cross-domain composition serves correctly in position-independent
/// mode (the §III.D approximation) and routes over the composed library.
#[test]
fn cross_domain_composition_serves() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServingConfig {
        top_k: Some(4),
        position_independent: true,
        ..Default::default()
    };
    let (mut eng, _svc) = build_engine(&dir, "native", cfg).unwrap();
    eng.register_composed("mix", "legal:0-3,code:0-3,medical:0-3")
        .unwrap();
    let dom = eng.shared.domain("mix").unwrap();
    assert_eq!(dom.n_chunks, 12);

    eng.submit(Some("mix"), vec![5, 6, 7, 8], 3, Sampler::Greedy).unwrap();
    let r = eng.run_to_completion().unwrap();
    assert_eq!(r[0].tokens.len(), 3);
    // router saw the composed chunk space
    assert!(eng.router.stats.chunks_scored > 0);
}

// ------------------------------------------------------- failure injection

/// A corrupted HLO artifact must fail loudly at compile time, not crash
/// or silently produce wrong numerics.
#[test]
fn corrupt_artifact_fails_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    // clone the artifacts tree shallowly into a temp dir
    let tmp = std::env::temp_dir().join("moska_corrupt_test");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(tmp.join("hlo")).unwrap();
    for sub in ["manifest.json"] {
        std::fs::copy(format!("{dir}/{sub}"), tmp.join(sub)).unwrap();
    }
    for entry in std::fs::read_dir(format!("{dir}/hlo")).unwrap() {
        let p = entry.unwrap().path();
        std::fs::copy(&p, tmp.join("hlo").join(p.file_name().unwrap()))
            .unwrap();
    }
    // corrupt one artifact
    std::fs::write(tmp.join("hlo/embed_b1.hlo.txt"), "HloModule broken(((")
        .unwrap();
    let svc = moska::runtime::RuntimeService::spawn(tmp.to_str().unwrap())
        .unwrap();
    let h = svc.handle();
    let emb = moska::tensor::Tensor::zeros_f32(&[256, 64]);
    let tok = moska::tensor::Tensor::zeros_i32(&[1]);
    let r = h.execute("embed_b1", vec![tok, emb]);
    assert!(r.is_err(), "corrupt HLO should fail to compile");
    // other artifacts still work
    let q = moska::tensor::Tensor::zeros_f32(&[1, 4, 16]);
    let k = moska::tensor::Tensor::zeros_f32(&[64, 2, 16]);
    let v = moska::tensor::Tensor::zeros_f32(&[64, 2, 16]);
    let qp = moska::tensor::Tensor::zeros_i32(&[1]);
    let r = h.execute(
        "chunk_attn_b1_c64",
        vec![q, k, v, qp, moska::tensor::Tensor::scalar_i32(0),
             moska::tensor::Tensor::scalar_i32(64)],
    );
    assert!(r.is_ok(), "{r:?}");
    let _ = std::fs::remove_dir_all(&tmp);
}

/// Missing artifacts dir → actionable error, not a panic.
#[test]
fn missing_artifacts_actionable_error() {
    let e = moska::runtime::Manifest::load("/nonexistent/nowhere")
        .unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("make artifacts"), "{msg}");
}

/// Engine with a starved page pool rejects at admission and never leaks.
#[test]
fn starved_pool_admission() {
    let Some(dir) = artifacts_dir() else { return };
    let man = moska::runtime::Manifest::load(&dir).unwrap();
    let weights = moska::model::Weights::load(
        man.weights_path().to_str().unwrap(), man.model.clone(),
    )
    .unwrap();
    let shared = moska::kvcache::SharedStore::empty(man.chunk);
    let be = Box::new(moska::runtime::NativeBackend::new(
        man.model.clone(), man.chunk,
    ));
    // 3 pages total: a 64-token prompt + generation needs ≥ 2 per layer
    let mut eng = Engine::new(be, weights, shared,
                              ServingConfig::default(), 3);
    let big: Vec<i32> = vec![1; 128];
    assert!(eng.submit(None, big, 64, Sampler::Greedy).is_err());
    // small request still fits
    eng.submit(None, vec![1, 2], 2, Sampler::Greedy).unwrap();
    let r = eng.run_to_completion().unwrap();
    assert_eq!(r[0].tokens.len(), 2);
    assert_eq!(eng.pool.allocated(), 0);
}

#[test]
fn composition_rejects_bad_refs() {
    let Some(dir) = artifacts_dir() else { return };
    let (mut eng, _svc) = dense_engine(&dir, "native");
    assert!(eng.register_composed("x", "nope:0-1").is_err());
    assert!(eng.register_composed("x", "code:900").is_err());
    assert!(eng.register_composed("legal", "code:0").is_err()); // dup name
}
