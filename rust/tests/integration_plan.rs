//! Plan/execute decode-pipeline integration (artifact-free: synthetic
//! weights + online-registered domains, native backend).
//!
//! Pins the three properties the StepPlan refactor must preserve:
//!
//! 1. **Numerics** — batched plan-driven decode produces exactly the
//!    tokens each request gets when decoded alone (batch forming,
//!    gather/scatter index tables, and LSE merge order are invisible);
//! 2. **Zero-alloc steady state** — after warm-up, the step arena stops
//!    allocating: every gather/partial/merge buffer recycles;
//! 3. **Session KV reuse** — multi-turn conversations over the
//!    plan/execute path match a fresh request with the concatenated
//!    history.

use moska::config::{ModelConfig, ServingConfig};
use moska::engine::Engine;
use moska::kvcache::SharedStore;
use moska::model::sampling::Sampler;
use moska::model::Weights;
use moska::runtime::NativeBackend;

const CHUNK: usize = 64;

fn native_engine(threads: usize, pool_pages: usize) -> Engine {
    let model = ModelConfig::tiny();
    let cfg = ServingConfig {
        top_k: None,
        exec_threads: threads,
        ..Default::default()
    };
    let be = NativeBackend::with_threads(model.clone(), CHUNK, threads);
    let weights = Weights::synthetic(model, 0x5EED);
    let mut eng = Engine::new(
        Box::new(be), weights, SharedStore::empty(CHUNK), cfg, pool_pages,
    );
    // two registered domains (2 and 3 chunks) exercise multi-group plans
    let alpha: Vec<i32> = (0..2 * CHUNK).map(|i| (i % 251) as i32).collect();
    let beta: Vec<i32> =
        (0..3 * CHUNK).map(|i| ((i * 7 + 3) % 251) as i32).collect();
    eng.register_domain("alpha", &alpha).expect("register alpha");
    eng.register_domain("beta", &beta).expect("register beta");
    eng
}

fn prompt(seed: i32) -> Vec<i32> {
    (0..8).map(|j| (seed * 37 + j * 11).rem_euclid(251)).collect()
}

/// Requests decoded inside a mixed batch (two domains + one
/// domain-less request) must produce exactly their solo tokens.
#[test]
fn batched_plan_decode_matches_solo() {
    let steps = 6;
    let specs: Vec<(Option<&str>, i32)> = vec![
        (Some("alpha"), 1),
        (Some("beta"), 2),
        (Some("alpha"), 3),
        (None, 4),
        (Some("beta"), 5),
    ];
    // solo references
    let mut want = Vec::new();
    for (dom, seed) in &specs {
        let mut solo = native_engine(1, 4096);
        solo.submit(*dom, prompt(*seed), steps, Sampler::Greedy).unwrap();
        want.push(solo.run_to_completion().unwrap().pop().unwrap().tokens);
    }
    // one batched engine
    let mut eng = native_engine(1, 4096);
    let mut ids = Vec::new();
    for (dom, seed) in &specs {
        ids.push(
            eng.submit(*dom, prompt(*seed), steps, Sampler::Greedy)
                .unwrap(),
        );
    }
    let results = eng.run_to_completion().unwrap();
    for (id, want) in ids.iter().zip(&want) {
        let got = &results.iter().find(|r| r.id == *id).unwrap().tokens;
        assert_eq!(got, want, "request {id} diverged in the batch");
    }
    // the shared path actually batched across requests
    assert!(eng.batching_factor() > 1.5,
            "batching factor {}", eng.batching_factor());
    assert_eq!(eng.pool.allocated(), 0, "pages leaked");
}

/// Steady-state decode performs zero heap allocations in arena-managed
/// paths: after warm-up steps, `fresh_allocs` must not move.
#[test]
fn steady_state_decode_is_arena_allocation_free() {
    let mut eng = native_engine(1, 4096);
    for i in 0..4i32 {
        // max_new keeps every request inside one unique-KV page, so the
        // step's buffer shapes are stable after warm-up
        eng.submit(Some("alpha"), prompt(10 + i), 40, Sampler::Greedy)
            .unwrap();
    }
    for _ in 0..10 {
        assert!(eng.step().unwrap(), "work ended during warm-up");
    }
    let stats = eng.arena_stats().clone();
    assert!(stats.high_water_bytes > 0, "arena unused by decode");
    assert!(stats.fresh_allocs > 0);
    for _ in 0..20 {
        assert!(eng.step().unwrap(), "work ended during measurement");
    }
    let after = eng.arena_stats();
    assert_eq!(
        after.fresh_allocs, stats.fresh_allocs,
        "steady-state decode allocated {} fresh arena buffers",
        after.fresh_allocs - stats.fresh_allocs
    );
    let results = eng.run_to_completion().unwrap();
    assert_eq!(results.len(), 4);
    assert!(results.iter().all(|r| r.tokens.len() == 40));
}

/// Two session turns over the plan/execute decode path == one fresh
/// request over the concatenated history (prefix KV reuse preserved).
#[test]
fn session_reuse_matches_fresh_request_native() {
    let mut eng = native_engine(1, 4096);
    let p1: Vec<i32> = vec![11, 22, 33, 44, 55, 66];
    let p2: Vec<i32> = vec![77, 88, 99];
    let (n1, n2) = (3usize, 4usize);

    let sid = eng.open_session(Some("beta")).unwrap();
    eng.submit_turn(sid, p1.clone(), n1, Sampler::Greedy).unwrap();
    let gen1 = eng.run_to_completion().unwrap().pop().unwrap().tokens;
    assert_eq!(gen1.len(), n1);
    eng.submit_turn(sid, p2.clone(), n2, Sampler::Greedy).unwrap();
    let gen2 = eng.run_to_completion().unwrap().pop().unwrap().tokens;
    assert_eq!(gen2.len(), n2);
    assert_eq!(eng.session(sid).unwrap().turns, 2);

    // fresh request: prompt = p1 ++ gen1 ++ p2 (same visible history)
    let mut full = p1;
    full.extend_from_slice(&gen1);
    full.extend_from_slice(&p2);
    let mut fresh = native_engine(1, 4096);
    fresh.submit(Some("beta"), full, n2, Sampler::Greedy).unwrap();
    let want = fresh.run_to_completion().unwrap().pop().unwrap().tokens;
    assert_eq!(gen2, want, "session turn-2 diverged from fresh request");

    let before = eng.pool.allocated();
    assert!(before > 0, "session parked no KV");
    eng.close_session(sid).unwrap();
    assert_eq!(eng.pool.allocated(), 0);
}

/// Admission: a request whose worst-case demand exactly equals the free
/// pool is admitted and completes; one page less is rejected up front.
#[test]
fn admission_exact_page_fit_engine_level() {
    // tiny model: 2 layers; prompt 4 + max_new 4 → 1 page per layer
    let model = ModelConfig::tiny();
    let mk = |pages: usize| {
        let be = NativeBackend::with_threads(model.clone(), CHUNK, 1);
        let weights = Weights::synthetic(model.clone(), 0xF17);
        Engine::new(Box::new(be), weights, SharedStore::empty(CHUNK),
                    ServingConfig::default(), pages)
    };
    let mut exact = mk(2);
    exact
        .submit(None, vec![1, 2, 3, 4], 4, Sampler::Greedy)
        .expect("exact fit must admit");
    let r = exact.run_to_completion().unwrap();
    assert_eq!(r[0].tokens.len(), 4);
    assert_eq!(exact.pool.allocated(), 0);

    let mut starved = mk(1);
    let err = starved
        .submit(None, vec![1, 2, 3, 4], 4, Sampler::Greedy)
        .unwrap_err();
    assert!(format!("{err:#}").contains("KV pages"), "{err:#}");
}

/// The route-live plan branch (`route_every_layer`) still decodes and
/// routes per layer.
#[test]
fn route_every_layer_plan_branch_decodes() {
    let model = ModelConfig::tiny();
    let cfg = ServingConfig {
        top_k: Some(1),
        route_every_layer: true,
        exec_threads: 1,
        ..Default::default()
    };
    let be = NativeBackend::with_threads(model.clone(), CHUNK, 1);
    let weights = Weights::synthetic(model, 0x0DD);
    let mut eng = Engine::new(
        Box::new(be), weights, SharedStore::empty(CHUNK), cfg, 4096,
    );
    let dom: Vec<i32> = (0..4 * CHUNK).map(|i| (i % 199) as i32).collect();
    eng.register_domain("d", &dom).unwrap();
    let queries_before = eng.router.stats.queries;
    eng.submit(Some("d"), prompt(9), 5, Sampler::Greedy).unwrap();
    let r = eng.run_to_completion().unwrap();
    assert_eq!(r[0].tokens.len(), 5);
    // per-layer routing: every decode step scores 2 layers' queries
    // (tiny model), so the counter grows faster than once per step
    let routed = eng.router.stats.queries - queries_before;
    assert!(routed >= 10, "expected per-layer routing, saw {routed}");
}
