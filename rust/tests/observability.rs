//! Observability surface tests: the `/stats` JSON shape (lifecycle
//! section included), the `/metrics` Prometheus text exposition, and
//! the exported Chrome-trace span timeline — all against a synthetic
//! engine, no artifacts needed.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use moska::config::{ModelConfig, ServingConfig};
use moska::engine::Engine;
use moska::kvcache::SharedStore;
use moska::metrics::Metrics;
use moska::model::Weights;
use moska::runtime::NativeBackend;
use moska::trace::{self, Arg, SpanGuard};
use moska::util::json::Json;
use moska::util::rng::Rng;

const CHUNK: usize = 64;

fn synthetic_engine() -> Engine {
    let model = ModelConfig::tiny();
    let cfg = ServingConfig {
        top_k: None,
        max_batch: 8,
        exec_threads: 1,
        ..Default::default()
    };
    let be = NativeBackend::with_threads(model.clone(), CHUNK, 1);
    let weights = Weights::synthetic(model, 0x0B5E);
    let mut eng = Engine::new(
        Box::new(be), weights, SharedStore::empty(CHUNK), cfg, 1024,
    );
    let tokens: Vec<i32> =
        (0..2 * CHUNK).map(|i| (i % 100) as i32).collect();
    eng.register_domain("bench", &tokens).expect("register domain");
    eng
}

/// One HTTP exchange; returns (header block, body).
fn http(addr: SocketAddr, req: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).expect("send");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("read");
    match resp.split_once("\r\n\r\n") {
        Some((h, b)) => (h.to_string(), b.to_string()),
        None => (resp, String::new()),
    }
}

fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    http(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

/// Poll an endpoint until `ok(body)` or a deadline (the engine loop
/// refreshes its snapshots between decode steps).
fn poll_get(addr: SocketAddr, path: &str,
            ok: impl Fn(&str) -> bool) -> (String, String) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (head, body) = http_get(addr, path);
        if ok(&body) {
            return (head, body);
        }
        assert!(Instant::now() < deadline,
                "{path} never reached the expected state; last: {body}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn spawn_server() -> SocketAddr {
    let engine = synthetic_engine();
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = moska::server::serve_on(
            "127.0.0.1:0".parse().unwrap(), engine, Some(tx),
        );
    });
    rx.recv().expect("server ready")
}

/// `/stats` carries the engine snapshot plus the per-request lifecycle
/// section (completed / queue / TTFT / TPOT means) after a generation,
/// and `/metrics` serves the same registry as Prometheus text.
#[test]
fn stats_and_metrics_endpoints_shape() {
    let addr = spawn_server();

    let body = r#"{"prompt": "ab", "domain": "bench", "max_tokens": 4}"#;
    let (head, resp) = http(addr, &format!(
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(), body,
    ));
    assert!(head.starts_with("HTTP/1.1 200"), "{head}\n{resp}");
    let j = Json::parse(&resp).expect("generate reply JSON");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);

    // ---- /stats: lifecycle section present and populated
    let completed = |body: &str| {
        Json::parse(body)
            .ok()
            .and_then(|j| {
                j.get("lifecycle")
                    .and_then(|l| l.get("completed"))
                    .and_then(|c| c.as_f64())
                    .ok()
            })
            .unwrap_or(0.0)
            >= 1.0
    };
    let (_, body) = poll_get(addr, "/stats", completed);
    let j = Json::parse(&body).unwrap();
    assert!(j.get("engine").is_ok());
    let lc = j.get("lifecycle").unwrap();
    assert!(lc.get("completed").unwrap().as_f64().unwrap() >= 1.0);
    let ttft = lc.get("mean_ttft_secs").unwrap().as_f64().unwrap();
    assert!(ttft > 0.0, "TTFT must be positive after a completion");
    assert!(lc.get("max_ttft_secs").unwrap().as_f64().unwrap()
            >= ttft - 1e-12);
    assert!(lc.get("mean_queue_secs").unwrap().as_f64().unwrap() >= 0.0);
    assert!(lc.get("mean_tpot_secs").unwrap().as_f64().unwrap() >= 0.0);
    // the histogram twins of the lifecycle means ride in the engine
    // snapshot (quantile-capable, Prometheus-exported)
    let h = j.get("engine").unwrap().get("histograms").unwrap();
    assert!(h.get("req_ttft_ns").unwrap().get("count").unwrap()
             .as_f64().unwrap() >= 1.0);
    assert!(h.get("req_tpot_ns").unwrap().get("count").unwrap()
             .as_f64().unwrap() >= 1.0);

    // ---- /metrics: Prometheus text exposition of the same registry
    let (head, body) = poll_get(addr, "/metrics", |b| {
        b.contains("moska_requests_completed")
    });
    assert!(head.contains("text/plain; version=0.0.4"), "{head}");
    assert!(body.contains("# TYPE moska_requests_completed counter"));
    assert!(body.contains("# TYPE moska_decode_step_ns histogram"));
    assert!(body.contains("moska_req_ttft_ns_count"));
    // structural scan: every line is a comment or `name value`, names
    // carry the moska_ prefix, values parse as numbers
    for line in body.lines() {
        if line.is_empty() || line.starts_with("# ") {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let kind = rest.split_whitespace().nth(1).unwrap_or("");
                assert!(
                    matches!(kind, "counter" | "gauge" | "histogram"),
                    "bad TYPE line: {line}",
                );
            }
            continue;
        }
        let (name, value) =
            line.split_once(' ').unwrap_or_else(|| {
                panic!("unparseable exposition line: {line}")
            });
        assert!(name.starts_with("moska_"), "unprefixed metric: {line}");
        value.parse::<f64>().unwrap_or_else(|_| {
            panic!("non-numeric sample value: {line}")
        });
    }

    // unknown paths still 404
    let (head, _) = http_get(addr, "/nope");
    assert!(head.starts_with("HTTP/1.1 404"), "{head}");
}

/// The Prometheus renderer's contract, registry-level: sanitized
/// `moska_`-prefixed names, correct TYPE lines, and cumulative
/// monotonically non-decreasing histogram buckets that sum to `_count`.
#[test]
fn prometheus_text_renders_all_metric_kinds() {
    let m = Metrics::new();
    m.count("requests_submitted", 3);
    m.count("weird-name.x", 1);
    m.gauge("live_batch", 2.5);
    m.observe_ns("step_ns", 1_000);
    m.observe_ns("step_ns", 2_000);
    m.observe_ns("step_ns", 2_000_000);
    let text = m.prometheus_text();

    assert!(text.contains("# TYPE moska_requests_submitted counter\n\
                           moska_requests_submitted 3\n"));
    assert!(text.contains("moska_weird_name_x 1\n"),
            "name not sanitized: {text}");
    assert!(text.contains("# TYPE moska_live_batch gauge\n\
                           moska_live_batch 2.5\n"));
    assert!(text.contains("# TYPE moska_step_ns histogram"));
    assert!(text.contains("moska_step_ns_sum 2003000\n"));
    assert!(text.contains("moska_step_ns_count 3\n"));

    // bucket series: cumulative, non-decreasing, capped by _count
    let mut last = 0u64;
    let mut buckets = 0;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("moska_step_ns_bucket{le=") {
            let v: u64 = rest
                .split_whitespace()
                .nth(1)
                .expect("bucket value")
                .parse()
                .expect("bucket count");
            assert!(v >= last, "bucket series decreased: {line}");
            last = v;
            buckets += 1;
        }
    }
    assert!(buckets >= 2, "expected bucket series plus +Inf");
    assert_eq!(last, 3, "+Inf bucket must equal _count");
}

/// Exported trace JSON is well-formed Chrome-trace: parses, spans nest,
/// durations are non-negative, and remote (shared-node) spans land under
/// their registered pid carrying the client's trace id.
#[test]
fn trace_export_wellformed_and_remote_attribution() {
    trace::enable();
    assert!(trace::enabled());
    let tid_str = trace::fmt_trace_id(trace::trace_id());
    assert!(tid_str.starts_with("0x") && tid_str.len() == 18);

    // a nested scoped pair: the inner span must sit inside the outer
    let outer_id;
    let inner_id;
    {
        let outer = SpanGuard::start("obs.outer", "test", vec![]);
        outer_id = outer.id();
        {
            let mut inner = SpanGuard::start(
                "obs.inner", "test", vec![("k", Arg::from(7u64))],
            );
            inner.arg("later", "x");
            inner_id = inner.id();
        }
    }
    assert!(outer_id > 0 && inner_id > outer_id);

    // a randomized bag of explicit-timing records
    let mut rng = Rng::new(0x0B5E_C0DE);
    let n = 40 + rng.below(40) as usize;
    for i in 0..n {
        trace::record(format!("obs.rand.{i}"), "test", trace::now_ns(),
                      rng.below(1_000_000), vec![("i", Arg::from(i))]);
    }

    // remote spans as the wire-echo path records them: mapped onto the
    // client clock, under a registered remote pid, tagged with the
    // client's trace id
    let pid = trace::register_remote_process("obs shared-node");
    assert!(pid >= 2, "remote pids start after the local process");
    for i in 0..5i64 {
        trace::record_remote(
            pid, format!("obs.remote.{i}"), i * 1_000, 500,
            vec![("trace_id", Arg::from(tid_str.clone()))],
        );
    }

    let body = trace::export_json_string();
    let j = Json::parse(&body).expect("trace JSON parses");
    assert_eq!(
        j.get("otherData").unwrap().get("trace_id").unwrap()
            .as_str().unwrap(),
        tid_str,
    );
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();

    let (mut outer, mut inner) = (None, None);
    let (mut rand_seen, mut remote_seen, mut meta_for_pid) = (0, 0, false);
    for e in evs {
        match e.get("ph").unwrap().as_str().unwrap() {
            "M" => {
                // process-name metadata must label registered pids
                e.get("args").unwrap().get("name").unwrap()
                    .as_str().unwrap();
                if e.get("pid").unwrap().as_f64().unwrap() as u32 == pid {
                    meta_for_pid = true;
                }
            }
            "X" => {
                let name = e.get("name").unwrap().as_str().unwrap();
                let ts = e.get("ts").unwrap().as_f64().unwrap();
                let dur = e.get("dur").unwrap().as_f64().unwrap();
                assert!(dur >= 0.0, "negative duration on {name}");
                let epid = e.get("pid").unwrap().as_f64().unwrap();
                assert!(epid >= 1.0);
                e.get("tid").unwrap().as_f64().unwrap();
                if name == "obs.outer" {
                    outer = Some((ts, dur));
                } else if name == "obs.inner" {
                    inner = Some((ts, dur));
                    let sid = e.get("args").unwrap().get("span_id")
                        .unwrap().as_f64().unwrap();
                    assert_eq!(sid as u64, inner_id);
                } else if name.starts_with("obs.rand.") {
                    rand_seen += 1;
                } else if name.starts_with("obs.remote.") {
                    remote_seen += 1;
                    assert_eq!(epid as u32, pid);
                    assert_eq!(e.get("cat").unwrap().as_str().unwrap(),
                               "remote");
                    assert_eq!(
                        e.get("args").unwrap().get("trace_id").unwrap()
                            .as_str().unwrap(),
                        tid_str,
                        "remote span lost the client's trace id",
                    );
                }
            }
            other => panic!("unexpected event phase {other}"),
        }
    }
    assert_eq!(rand_seen, n, "a recorded span went missing");
    assert_eq!(remote_seen, 5);
    assert!(meta_for_pid, "remote process has no name metadata");
    let (ots, odur) = outer.expect("outer span exported");
    let (its, idur) = inner.expect("inner span exported");
    // nesting (µs floats; 2ns slack for the division rounding)
    assert!(its >= ots - 0.002 && its + idur <= ots + odur + 0.002,
            "inner span [{its}, {}] escapes outer [{ots}, {}]",
            its + idur, ots + odur);
}
