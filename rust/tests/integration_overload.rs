//! Overload-control tests: SLO-aware admission shedding under a flood
//! (batch 429s strictly before interactive, every 429 carrying a
//! `Retry-After` hint), deadline expiry releasing KV pages and counting
//! as a lifecycle timeout, the mid-stream `event: error` timeout frame,
//! and a property that admission/expiry/cancel never strand pool pages.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use moska::config::{ModelConfig, ServingConfig};
use moska::engine::{Engine, SubmitOpts};
use moska::kvcache::SharedStore;
use moska::model::sampling::Sampler;
use moska::model::Weights;
use moska::prop_assert;
use moska::runtime::NativeBackend;
use moska::scheduler::{AdmissionConfig, Priority};
use moska::util::json::Json;
use moska::util::prop::{check, Case, Config};

const CHUNK: usize = 64;

/// The integration_serving synthetic engine, with the serving config
/// (admission watermarks, deadlines) chosen by the caller.
fn engine_with(tune: impl FnOnce(&mut ServingConfig)) -> Engine {
    let model = ModelConfig::tiny();
    let mut cfg = ServingConfig {
        top_k: None,
        max_batch: 8,
        exec_threads: 1,
        ..Default::default()
    };
    tune(&mut cfg);
    let be = NativeBackend::with_threads(model.clone(), CHUNK, 1);
    let weights = Weights::synthetic(model, 0x0B5E);
    let mut eng = Engine::new(
        Box::new(be), weights, SharedStore::empty(CHUNK), cfg, 1024,
    );
    let tokens: Vec<i32> =
        (0..2 * CHUNK).map(|i| (i % 100) as i32).collect();
    eng.register_domain("bench", &tokens).expect("register domain");
    eng
}

fn spawn_server(engine: Engine) -> SocketAddr {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = moska::server::serve_on(
            "127.0.0.1:0".parse().unwrap(), engine, Some(tx),
        );
    });
    rx.recv().expect("server ready")
}

/// One HTTP exchange; returns (header block, body).
fn http(addr: SocketAddr, req: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).expect("send");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("read");
    match resp.split_once("\r\n\r\n") {
        Some((h, b)) => (h.to_string(), b.to_string()),
        None => (resp, String::new()),
    }
}

fn post_generate(addr: SocketAddr, body: &str) -> (String, String) {
    http(addr, &format!(
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(), body,
    ))
}

fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    http(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

/// Poll an endpoint until `ok(body)` or a deadline.
fn poll_get(addr: SocketAddr, path: &str,
            ok: impl Fn(&str) -> bool) -> (String, String) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (head, body) = http_get(addr, path);
        if ok(&body) {
            return (head, body);
        }
        assert!(Instant::now() < deadline,
                "{path} never reached the expected state; last: {body}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn stat(j: &Json, k: &str) -> f64 {
    j.get(k).ok().and_then(|v| v.as_f64().ok()).unwrap_or(-1.0)
}

/// Flood a tight-watermark server with batch work plus a handful of
/// interactive requests: batch is shed (429 + Retry-After) strictly
/// before interactive (zero interactive rejections), the shed counters
/// show up on /stats, and the server drains cleanly afterwards.
#[test]
fn flood_sheds_batch_before_interactive_with_retry_after() {
    // watermarks low enough that ~7 queued requests escalate to level 1
    let engine = engine_with(|cfg| {
        cfg.admission = AdmissionConfig {
            enabled: true,
            max_queue: 64,
            max_queued_prefill_tokens: 1_000_000,
            high: 0.10,
            low: 0.05,
            retry_after_secs: 0.5,
        };
    });
    let addr = spawn_server(engine);

    let fire = |priority: &'static str| {
        std::thread::spawn(move || {
            let body = format!(
                r#"{{"prompt": "abcdef", "domain": "bench", "max_tokens": 24, "priority": "{priority}"}}"#,
            );
            post_generate(addr, &body)
        })
    };
    // 48 batch clients first (queue depth crosses the high watermark
    // while they are still arriving), then 8 interactive clients
    let batch: Vec<_> = (0..48).map(|_| fire("batch")).collect();
    let interactive: Vec<_> = (0..8).map(|_| fire("interactive")).collect();

    let mut batch_shed = 0usize;
    for h in batch {
        let (head, body) = h.join().expect("batch client");
        if head.starts_with("HTTP/1.1 429") {
            batch_shed += 1;
            assert!(head.contains("Retry-After:"),
                    "429 without Retry-After: {head}");
            let j = Json::parse(&body).expect("429 body JSON");
            assert!(j.get("error").is_ok(), "429 body lacks error: {body}");
        } else {
            assert!(head.starts_with("HTTP/1.1 200"), "{head}\n{body}");
        }
    }
    for h in interactive {
        let (head, body) = h.join().expect("interactive client");
        assert!(head.starts_with("HTTP/1.1 200"),
                "interactive request rejected under batch flood: \
                 {head}\n{body}");
    }
    assert!(batch_shed > 0,
            "flood never tripped the batch watermark");

    // server drains: nothing live/queued, all pages back
    let (_, stats) = poll_get(addr, "/stats", |body| {
        let Ok(j) = Json::parse(body) else { return false };
        stat(&j, "live") == 0.0 && stat(&j, "queued") == 0.0
            && stat(&j, "kv_pages_allocated") == 0.0
    });
    let j = Json::parse(&stats).unwrap();
    let adm = j.get("admission").expect("admission stats");
    assert_eq!(stat(adm, "shed_batch") as usize, batch_shed,
               "/stats shed_batch disagrees with observed 429s");
    assert_eq!(stat(adm, "shed_interactive"), 0.0, "{adm:?}");
}

/// Deadline expiry is a clean retirement: a queued request past its
/// deadline never runs, a mid-flight request past its deadline releases
/// every KV page it held, both count as lifecycle timeouts and neither
/// as a completion.
#[test]
fn deadline_expiry_releases_pages_and_counts_timeouts() {
    let mut eng = engine_with(|_| {});

    // (1) expires while still queued: a zero deadline is already past
    // by the first step's expiry sweep
    let id = eng
        .submit_with(Some("bench"), vec![1, 2, 3], 8, Sampler::Greedy,
                     SubmitOpts {
                         deadline: Some(Duration::ZERO),
                         ..Default::default()
                     })
        .expect("submit");
    eng.step().expect("step");
    let expired = eng.take_expired();
    assert_eq!(expired.len(), 1, "{expired:?}");
    assert_eq!(expired[0].0, id);
    assert!(expired[0].1.contains("deadline"), "{}", expired[0].1);
    assert!(eng.take_results().is_empty(), "expired request completed");
    assert!(!eng.has_work());
    assert_eq!(eng.pool.allocated(), 0);

    // (2) expires mid-flight: long generation, short deadline — step
    // until the expiry sweep cancels it, then its pages must be back
    let id = eng
        .submit_with(Some("bench"), vec![4, 5, 6, 7], 20_000,
                     Sampler::Greedy,
                     SubmitOpts {
                         deadline: Some(Duration::from_millis(30)),
                         ..Default::default()
                     })
        .expect("submit");
    let deadline = Instant::now() + Duration::from_secs(10);
    let expired = loop {
        eng.step().expect("step");
        let e = eng.take_expired();
        if !e.is_empty() {
            break e;
        }
        assert!(Instant::now() < deadline, "request never expired");
    };
    assert_eq!(expired[0].0, id);
    assert!(eng.take_results().is_empty(), "expired request completed");
    assert_eq!(eng.pool.allocated(), 0,
               "mid-flight expiry stranded KV pages");
    assert_eq!(eng.lifecycle.timeouts(), 2);
    assert_eq!(eng.lifecycle.completed(), 0);
}

/// A request that times out after streaming began gets a terminal
/// `event: error` SSE frame whose JSON body says `"kind": "timeout"` —
/// not a silently closed socket.
#[test]
fn midstream_timeout_emits_error_frame() {
    let addr = spawn_server(engine_with(|_| {}));
    // generation far longer than the deadline, so the first tokens
    // stream and then the expiry sweep cancels the request mid-stream
    let body = r#"{"prompt": "abcd", "domain": "bench", "max_tokens": 20000, "stream": true, "deadline_ms": 400}"#;
    let (head, body) = post_generate(addr, body);
    assert!(head.starts_with("HTTP/1.1 200"), "{head}\n{body}");
    assert!(head.contains("text/event-stream"), "{head}");
    assert!(body.contains("data: {\"token\""),
            "no tokens streamed before the timeout: {body}");
    let frame = body
        .split("\n\n")
        .find_map(|f| f.strip_prefix("event: error\ndata: "))
        .unwrap_or_else(|| panic!("no error frame in: {body}"));
    let j = Json::parse(frame).expect("error frame JSON");
    assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "timeout",
               "{frame}");
    assert!(!j.get("error").unwrap().as_str().unwrap().is_empty());
    assert!(!body.contains("event: done"),
            "timed-out stream also claimed completion: {body}");

    // the cancelled request releases its pages and counts as a timeout
    let (_, stats) = poll_get(addr, "/stats", |body| {
        let Ok(j) = Json::parse(body) else { return false };
        stat(&j, "live") == 0.0 && stat(&j, "queued") == 0.0
            && stat(&j, "kv_pages_allocated") == 0.0
    });
    let j = Json::parse(&stats).unwrap();
    let lc = j.get("lifecycle").unwrap();
    assert_eq!(stat(lc, "timeouts"), 1.0, "{lc:?}");
    assert_eq!(stat(lc, "completed"), 0.0, "{lc:?}");
}

// ---------------------------------------------------------------- property

/// One randomized overload episode: watermarks, a small page pool, and
/// a submit/step/cancel/instant-deadline mix.
#[derive(Debug, Clone)]
struct OverloadCase {
    high: f64,
    low: f64,
    max_queue: usize,
    /// (prompt len, max_new, class 0..3, instant deadline, cancel)
    reqs: Vec<(usize, usize, u8, bool, bool)>,
    steps_between: usize,
}

impl Case for OverloadCase {
    fn shrink(&self) -> Vec<OverloadCase> {
        let mut out = Vec::new();
        if self.reqs.len() > 1 {
            out.push(OverloadCase {
                reqs: self.reqs[..self.reqs.len() / 2].to_vec(),
                ..self.clone()
            });
            out.push(OverloadCase {
                reqs: self.reqs[1..].to_vec(),
                ..self.clone()
            });
        }
        out
    }
}

fn gen_overload(rng: &mut moska::util::rng::Rng) -> OverloadCase {
    let high = 0.05 + rng.f64() * 0.9;
    let low = high * rng.f64();
    let n = rng.range(1, 25);
    let reqs = (0..n)
        .map(|_| {
            (rng.range(1, 9), rng.range(1, 9),
             rng.range(0, 3) as u8, rng.f64() < 0.2, rng.f64() < 0.15)
        })
        .collect();
    OverloadCase {
        high,
        low,
        max_queue: rng.range(2, 17),
        reqs,
        steps_between: rng.range(0, 4),
    }
}

/// Whatever the admission verdicts, deadline expiries, and client
/// cancels along the way, a drained engine owes the pool every page:
/// rejections must not reserve, expiries and cancels must release.
#[test]
fn prop_admission_never_strands_pages() {
    let cfg = Config { cases: 16, ..Default::default() };
    check("admission-pages-conserved", cfg, gen_overload, |case| {
        let mut eng = engine_with(|cfg| {
            cfg.max_batch = 4;
            cfg.admission = AdmissionConfig {
                enabled: true,
                max_queue: case.max_queue,
                max_queued_prefill_tokens: 64,
                high: case.high,
                low: case.low,
                retry_after_secs: 0.1,
            };
        });
        let capacity = eng.pool.capacity();
        for &(plen, max_new, class, instant, cancel) in &case.reqs {
            let priority = match class {
                0 => Priority::Interactive,
                1 => Priority::Standard,
                _ => Priority::Batch,
            };
            let sub = eng.submit_with(
                None, vec![7; plen], max_new, Sampler::Greedy,
                SubmitOpts {
                    priority,
                    deadline: instant.then_some(Duration::ZERO),
                    ..Default::default()
                },
            );
            if let Ok(id) = sub {
                if cancel {
                    eng.cancel(id);
                }
            }
            for _ in 0..case.steps_between {
                eng.step().map_err(|e| e.to_string())?;
            }
        }
        for _ in 0..50_000 {
            if !eng.step().map_err(|e| e.to_string())? {
                break;
            }
        }
        eng.take_expired();
        eng.take_results();
        prop_assert!(!eng.has_work(), "engine never drained: {case:?}");
        prop_assert!(
            eng.pool.allocated() == 0
                && eng.pool.available() == capacity,
            "pages stranded: {} allocated, {}/{} available",
            eng.pool.allocated(), eng.pool.available(), capacity
        );
        Ok(())
    });
}
