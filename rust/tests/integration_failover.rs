//! Elastic-fabric chaos tests — all loopback, no artifacts. Two real
//! `shared-node` servers each hold the FULL synthetic store, so every
//! domain is a 2-replica set. Killing one replica mid-decode must not
//! change a single output bit (plan execution is pure; unreplied frames
//! are re-placed on the survivor verbatim), and losing the LAST replica
//! must degrade to per-request errors — never a process abort.

use std::sync::Arc;
use std::time::Duration;

use moska::config::ModelConfig;
use moska::disagg::{parse_shard_specs, synthetic_store, synthetic_weights,
                    DisaggCluster, HealthCfg, ShardedFabric,
                    SYNTH_CHUNK, SYNTH_DOMAIN, SYNTH_DOMAIN_B};
use moska::remote::{spawn_shared_node_ctl, TransportCfg};
use moska::runtime::{Backend, NativeBackend};

fn native_be() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::with_threads(ModelConfig::tiny(), SYNTH_CHUNK,
                                         1))
}

fn test_cfg() -> TransportCfg {
    TransportCfg {
        connect_attempts: 20,
        reconnect_attempts: 20,
        connect_backoff: Duration::from_millis(25),
        connect_backoff_cap: Duration::from_millis(100),
        request_retries: 2,
        read_timeout: Duration::from_secs(2),
    }
}

fn all_domains() -> Vec<String> {
    vec![SYNTH_DOMAIN.to_string(), SYNTH_DOMAIN_B.to_string()]
}

/// The chaos acceptance criterion: with every domain held by two
/// replicas, killing one replica between decode points re-routes (and
/// where needed re-sends) to the survivor, the token streams stay
/// bit-identical to an uninterrupted in-process run, and the elastic
/// counters record the failover.
#[test]
fn kill_one_replica_mid_decode_stays_bit_identical() {
    let (a, ctl_a) = spawn_shared_node_ctl(
        native_be(), Arc::new(synthetic_store().unwrap()),
    )
    .unwrap();
    let (b, _ctl_b) = spawn_shared_node_ctl(
        native_be(), Arc::new(synthetic_store().unwrap()),
    )
    .unwrap();

    // both shards hold both domains → every domain is a 2-replica set
    let specs = parse_shard_specs(&format!("{a},{b}")).unwrap();
    let (fabric, store) =
        ShardedFabric::connect(&specs, test_cfg(), HealthCfg::default())
            .unwrap();
    assert_eq!(
        fabric.assignment(),
        vec![(SYNTH_DOMAIN.to_string(), vec![0, 1]),
             (SYNTH_DOMAIN_B.to_string(), vec![0, 1])],
    );
    let mut sharded = DisaggCluster::with_fabric(
        native_be(), Box::new(fabric), synthetic_weights(),
        Arc::new(store), Some(4), 32,
    );
    // point 1: both replicas healthy and round-robin routed
    let p1 = sharded.run_point_mixed(2, &all_domains(), 32, 3).unwrap();
    assert!(p1.errors.is_empty(), "{:?}", p1.errors);

    // chaos: kill replica 0. Its listener closes and every open
    // connection is force-shut, so the fabric's next frames to it die
    // mid-flight and must be re-placed on replica 1.
    ctl_a.shutdown(Duration::from_millis(250));

    // point 2: decodes to completion through the survivor
    let p2 = sharded.run_point_mixed(2, &all_domains(), 32, 3).unwrap();
    assert!(p2.errors.is_empty(),
            "survivor should absorb the batch: {:?}", p2.errors);

    let el = sharded.fabric_elastic().expect("sharded fabric is elastic");
    assert!(el.failovers >= 1, "no failover recorded: {el:?}");
    assert!(el.resent_frames >= 1, "no frames re-placed: {el:?}");
    assert_ne!(el.health[0], 0, "killed replica still marked healthy");

    // bit-identity: an uninterrupted in-process run over the same two
    // points produces the exact same token streams
    let mut local = DisaggCluster::with_backends(
        native_be(), native_be(), synthetic_weights(),
        Arc::new(synthetic_store().unwrap()), Some(4), 32,
    );
    let l1 = local.run_point_mixed(2, &all_domains(), 32, 3).unwrap();
    let l2 = local.run_point_mixed(2, &all_domains(), 32, 3).unwrap();
    assert_eq!(l1.tokens, p1.tokens,
               "pre-kill decode diverged from in-process");
    assert_eq!(l2.tokens, p2.tokens,
               "post-failover decode diverged from in-process");
}

/// Losing the ONLY replica of a domain degrades to per-request errors
/// carried in [`SimPoint::errors`]: every request in the batch is
/// reported (with its original row) and the point still returns `Ok` —
/// the engine never aborts the process for a dead shard.
#[test]
fn no_surviving_replica_degrades_to_per_request_errors() {
    let (a, ctl_a) = spawn_shared_node_ctl(
        native_be(), Arc::new(synthetic_store().unwrap()),
    )
    .unwrap();
    let specs = parse_shard_specs(&a.to_string()).unwrap();
    let (fabric, store) =
        ShardedFabric::connect(&specs, test_cfg(), HealthCfg::default())
            .unwrap();
    let mut sharded = DisaggCluster::with_fabric(
        native_be(), Box::new(fabric), synthetic_weights(),
        Arc::new(store), Some(4), 32,
    );
    // a healthy warm-up point, then the only replica dies
    let p1 = sharded.run_point_mixed(2, &all_domains(), 32, 2).unwrap();
    assert!(p1.errors.is_empty(), "{:?}", p1.errors);
    ctl_a.shutdown(Duration::from_millis(250));

    let p2 = sharded.run_point_mixed(4, &all_domains(), 32, 3).unwrap();
    // every row errors (each domain loses its last replica), nobody
    // decodes, and the KV pool is left clean for the next batch
    assert_eq!(p2.errors.len(), 4, "{:?}", p2.errors);
    let mut rows: Vec<usize> = p2.errors.iter().map(|(r, _)| *r).collect();
    rows.sort_unstable();
    assert_eq!(rows, vec![0, 1, 2, 3]);
    for (_, msg) in &p2.errors {
        assert!(msg.contains("no surviving replica"), "{msg}");
    }
    assert!(p2.tokens.iter().all(|t| t.is_empty()),
            "dropped requests must not emit tokens: {:?}", p2.tokens);
    let el = sharded.fabric_elastic().unwrap();
    assert_ne!(el.health[0], 0, "dead shard still marked healthy");
}
