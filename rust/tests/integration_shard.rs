//! Domain-sharded fabric integration tests — all loopback, no
//! artifacts. Two real `shared-node` servers each hold a *partitioned*
//! synthetic store (`SharedStore::retain_domains`), the unique node
//! builds its planner view purely from the `Sync` handshake (never
//! mapping shared K/V into its process), and the sharded decode must be
//! bit-identical to both the single-node remote run and the in-process
//! run.

use std::io::Write;
use std::net::TcpListener;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use moska::config::ModelConfig;
use moska::disagg::{parse_shard_specs, synthetic_store, synthetic_weights,
                    DisaggCluster, HealthCfg, ShardedFabric, SharedFabric,
                    SYNTH_CHUNK, SYNTH_DOMAIN, SYNTH_DOMAIN_B};
use moska::kvcache::shared_store::{DomainPlannerState, SharedStore};
use moska::plan::SharedGroupPlan;
use moska::remote::codec::{self, HelloAck, StoreSync, WireMsg};
use moska::remote::{spawn_shared_node, RemoteFabric, TransportCfg};
use moska::runtime::native::Partials;
use moska::runtime::{Backend, NativeBackend};
use moska::tensor::Tensor;

fn native_be() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::with_threads(ModelConfig::tiny(), SYNTH_CHUNK,
                                         1))
}

fn test_cfg() -> TransportCfg {
    TransportCfg {
        connect_attempts: 20,
        reconnect_attempts: 20,
        connect_backoff: Duration::from_millis(25),
        connect_backoff_cap: Duration::from_millis(100),
        request_retries: 2,
        read_timeout: Duration::from_secs(2),
    }
}

fn health_cfg() -> HealthCfg {
    HealthCfg::default()
}

fn all_domains() -> Vec<String> {
    vec![SYNTH_DOMAIN.to_string(), SYNTH_DOMAIN_B.to_string()]
}

/// One shard's slice of the synthetic store — exactly what a real
/// `moska shared-node --synthetic --domains <keep>` process serves.
fn partition(keep: &str) -> Arc<SharedStore> {
    let mut s = synthetic_store().unwrap();
    s.retain_domains(&[keep.to_string()]).unwrap();
    Arc::new(s)
}

/// The acceptance criterion: a 2-shard run over partitioned stores is
/// bit-identical to the single-node remote run and the in-process run,
/// with the unique node holding zero shared K/V on both remote paths.
#[test]
fn sharded_decode_bit_identical_to_single_node_and_in_process() {
    let domains = all_domains();
    let full = Arc::new(synthetic_store().unwrap());

    // in-process baseline: full store, LocalFabric
    let mut local = DisaggCluster::with_backends(
        native_be(), native_be(), synthetic_weights(), Arc::clone(&full),
        Some(4), 32,
    );
    let pl = local.run_point_mixed(3, &domains, 32, 4).unwrap();

    // single remote node holding the full store; the planner view comes
    // from Sync, not from a local load
    let addr =
        spawn_shared_node(native_be(), Arc::clone(&full)).unwrap();
    let mut f =
        RemoteFabric::connect(&addr.to_string(), test_cfg()).unwrap();
    let sync = f.sync().unwrap();
    assert_eq!(sync.digest, full.content_digest());
    let view =
        SharedStore::from_planner_states(sync.chunk, sync.domains)
            .unwrap();
    assert_eq!(view.resident_bytes(), 0,
               "unique node must hold no shared K/V");
    let mut single = DisaggCluster::with_fabric(
        native_be(), Box::new(f), synthetic_weights(), Arc::new(view),
        Some(4), 32,
    );
    let ps = single.run_point_mixed(3, &domains, 32, 4).unwrap();

    // two shards over partitioned stores, assignment from residency
    let a = spawn_shared_node(native_be(), partition(SYNTH_DOMAIN))
        .unwrap();
    let b = spawn_shared_node(native_be(), partition(SYNTH_DOMAIN_B))
        .unwrap();
    let specs = parse_shard_specs(&format!("{a},{b}")).unwrap();
    let (fabric, store) =
        ShardedFabric::connect(&specs, test_cfg(), health_cfg()).unwrap();
    assert_eq!(store.resident_bytes(), 0,
               "unique node must hold no shared K/V when sharded");
    assert_eq!(store.domains.len(), 2);
    assert_eq!(
        fabric.assignment(),
        vec![(SYNTH_DOMAIN.to_string(), vec![0]),
             (SYNTH_DOMAIN_B.to_string(), vec![1])],
    );
    // feed the derived assignment to the step planner: shard-contiguous
    // group ordering must not change a single output bit
    let mut asn = moska::plan::ShardAssignment::new();
    for (d, replicas) in fabric.assignment() {
        for &s in &replicas {
            asn.assign(&d, s).unwrap();
        }
    }
    let mut sharded = DisaggCluster::with_fabric(
        native_be(), Box::new(fabric), synthetic_weights(),
        Arc::new(store), Some(4), 32,
    );
    sharded.shard_assignment = Some(asn);
    let p2 = sharded.run_point_mixed(3, &domains, 32, 4).unwrap();

    assert_eq!(pl.tokens, ps.tokens,
               "single-node remote decode diverged from in-process");
    assert_eq!(pl.tokens, p2.tokens,
               "sharded decode diverged from in-process");

    // both shards really executed work, and the per-shard counters are
    // the labeled observability surface
    let stats = sharded.fabric_shard_stats();
    assert_eq!(stats.len(), 2);
    for (id, st) in &stats {
        assert!(st.frames_sent.load(Ordering::Relaxed) > 0,
                "shard {id} shipped no frames");
        assert!(st.bytes_recv.load(Ordering::Relaxed) > 0,
                "shard {id} returned no bytes");
    }
    for (id, _) in &stats {
        let g = |name: &str| {
            sharded
                .metrics
                .gauge_value(&format!("fabric_{name}_shard{id}"))
                .unwrap_or(0.0)
        };
        assert!(g("frames_sent") > 0.0,
                "per-shard gauge missing for shard {id}");
    }
}

/// A domain resident on several shards (with bit-identical planner
/// state) is a **replica set**: unpinned multi-residency connects,
/// round-robin routing spreads groups across both replicas, and the
/// replicated decode is still bit-identical to the in-process run.
/// Explicit pins narrow the set — and the pinned run also decodes
/// bit-identically.
#[test]
fn replicated_residency_load_balances_bit_identically() {
    let full_a = Arc::new(synthetic_store().unwrap());
    let full_b = Arc::new(synthetic_store().unwrap());
    let a = spawn_shared_node(native_be(), full_a).unwrap();
    let b = spawn_shared_node(native_be(), full_b).unwrap();

    // both shards hold both domains → every domain is a 2-replica set
    let specs = parse_shard_specs(&format!("{a},{b}")).unwrap();
    let (fabric, store) =
        ShardedFabric::connect(&specs, test_cfg(), health_cfg()).unwrap();
    assert_eq!(
        fabric.assignment(),
        vec![(SYNTH_DOMAIN.to_string(), vec![0, 1]),
             (SYNTH_DOMAIN_B.to_string(), vec![0, 1])],
    );
    let mut sharded = DisaggCluster::with_fabric(
        native_be(), Box::new(fabric), synthetic_weights(),
        Arc::new(store), Some(4), 32,
    );
    let p = sharded.run_point_mixed(2, &all_domains(), 32, 3).unwrap();
    // round-robin over healthy replicas: both shards really served
    let stats = sharded.fabric_shard_stats();
    assert_eq!(stats.len(), 2);
    for (id, st) in &stats {
        assert!(st.frames_sent.load(Ordering::Relaxed) > 0,
                "replica {id} was never routed to");
    }

    // pins narrow the replica sets down to a classic partition
    let specs = parse_shard_specs(&format!(
        "{}={a},{}={b}", SYNTH_DOMAIN, SYNTH_DOMAIN_B,
    ))
    .unwrap();
    let (fabric, store) =
        ShardedFabric::connect(&specs, test_cfg(), health_cfg()).unwrap();
    assert_eq!(
        fabric.assignment(),
        vec![(SYNTH_DOMAIN.to_string(), vec![0]),
             (SYNTH_DOMAIN_B.to_string(), vec![1])],
    );
    let mut pinned = DisaggCluster::with_fabric(
        native_be(), Box::new(fabric), synthetic_weights(),
        Arc::new(store), Some(4), 32,
    );
    let pp = pinned.run_point_mixed(2, &all_domains(), 32, 3).unwrap();

    let mut local = DisaggCluster::with_backends(
        native_be(), native_be(), synthetic_weights(),
        Arc::new(synthetic_store().unwrap()), Some(4), 32,
    );
    let pl = local.run_point_mixed(2, &all_domains(), 32, 3).unwrap();
    assert_eq!(pl.tokens, p.tokens,
               "replicated decode diverged from in-process");
    assert_eq!(pl.tokens, pp.tokens,
               "pinned decode diverged from in-process");
}

/// A pin naming a domain the shard does not hold is refused at connect.
#[test]
fn pin_to_non_resident_shard_refused() {
    let a = spawn_shared_node(native_be(), partition(SYNTH_DOMAIN))
        .unwrap();
    let specs =
        parse_shard_specs(&format!("{}={a}", SYNTH_DOMAIN_B)).unwrap();
    let err = ShardedFabric::connect(&specs, test_cfg(), health_cfg())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("not resident"), "{msg}");
}

/// One shard down at connect time fails cleanly (naming the shard),
/// not with a hang.
#[test]
fn shard_down_at_connect_fails_cleanly() {
    let a = spawn_shared_node(native_be(), partition(SYNTH_DOMAIN))
        .unwrap();
    // reserve a port and close it again — nothing listens there
    let probe = TcpListener::bind("127.0.0.1:0").unwrap();
    let dead = probe.local_addr().unwrap();
    drop(probe);
    let specs = parse_shard_specs(&format!("{a},{dead}")).unwrap();
    let cfg = TransportCfg {
        connect_attempts: 2,
        connect_backoff: Duration::from_millis(10),
        ..test_cfg()
    };
    let err =
        ShardedFabric::connect(&specs, cfg, health_cfg()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&dead.to_string()), "{msg}");
}

/// A flaky shard server: answers Hello/Sync (embeddings filled with
/// `fill`), serves exactly one ExecShared per connection, then drops it
/// — the sharded fabric must recover transparently through the
/// per-shard reconnect + resend path.
fn flaky_shard_with(domain: &'static str, fill: f32)
                    -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let state = DomainPlannerState {
            name: domain.to_string(),
            n_tokens: SYNTH_CHUNK,
            chunk_bases: vec![0],
            embs: vec![Tensor::f32(&[1, 2, 16], vec![fill; 32]); 2],
        };
        for stream in listener.incoming() {
            let Ok(mut s) = stream else { continue };
            loop {
                match codec::read_frame(&mut s) {
                    Ok((WireMsg::Hello, _)) => {
                        let ack = WireMsg::HelloAck(HelloAck {
                            chunk: SYNTH_CHUNK,
                            domains: vec![domain.to_string()],
                            digest: 7,
                            kv_dtype: moska::tensor::KvDtype::F32,
                            server_now_ns: 0,
                        });
                        if s.write_all(&codec::frame_bytes(&ack)).is_err()
                        {
                            break;
                        }
                    }
                    Ok((WireMsg::Sync, _)) => {
                        let reply = WireMsg::SyncState(StoreSync {
                            chunk: SYNTH_CHUNK,
                            digest: 7,
                            kv_dtype: moska::tensor::KvDtype::F32,
                            domains: vec![state.clone()],
                        });
                        if s.write_all(&codec::frame_bytes(&reply))
                            .is_err()
                        {
                            break;
                        }
                    }
                    Ok((WireMsg::ExecShared(_), _)) => {
                        let reply = WireMsg::Partials {
                            parts: vec![Partials::identity(1, 4, 16)],
                            exec_ns: 1,
                            trace_id: 0,
                            spans: Vec::new(),
                        };
                        let _ = s.write_all(&codec::frame_bytes(&reply));
                        break; // drop the conn after one request
                    }
                    _ => break,
                }
            }
        }
    });
    addr
}

fn flaky_shard(domain: &'static str) -> std::net::SocketAddr {
    flaky_shard_with(domain, 0.1)
}

/// Two shards advertising the same domain with *different* planner
/// state are a diverged deployment — refused at connect even when a
/// pin would pick one of them.
#[test]
fn diverged_multi_resident_domain_refused() {
    let a = flaky_shard_with("doma", 0.1);
    let b = flaky_shard_with("doma", 0.2);
    let specs =
        parse_shard_specs(&format!("doma={a},{b}")).unwrap();
    let err = ShardedFabric::connect(&specs, test_cfg(), health_cfg())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("different planner state"), "{msg}");
}

/// One shard dropping its connection mid-run surfaces as retry +
/// recovery inside that shard's fabric; the step as a whole succeeds.
#[test]
fn shard_drop_mid_run_retries_and_recovers() {
    let a = flaky_shard("doma");
    let b = flaky_shard("domb");
    let specs = parse_shard_specs(&format!("{a},{b}")).unwrap();
    let (mut fabric, store) =
        ShardedFabric::connect(&specs, test_cfg(), health_cfg()).unwrap();
    assert_eq!(store.domains.len(), 2);

    let q = Tensor::f32(&[1, 4, 16], vec![0.25; 64]);
    let plan = |d: &str| SharedGroupPlan {
        domain: d.to_string(),
        rows: vec![0],
        q_pos: vec![10],
        sets: vec![vec![]],
        calls: vec![],
        pairs: 0,
        reads: 0,
    };
    let (pa, pb) = (plan("doma"), plan("domb"));
    for round in 0..3 {
        fabric.submit(0, &[(&q, &pa), (&q, &pb)]).unwrap();
        let replies = fabric.collect().unwrap_or_else(|e| {
            panic!("round {round} failed: {e:#}")
        });
        assert_eq!(replies.len(), 2, "round {round}");
    }
    // rounds 2+ must have hit each shard's reconnect path
    let retries: u64 = fabric
        .shard_stats()
        .iter()
        .map(|(_, st)| st.retries.load(Ordering::Relaxed))
        .sum();
    assert!(retries >= 1, "no shard retried ({retries})");
}

/// A group for a domain no shard serves is refused at submit, before
/// anything crosses the wire.
#[test]
fn unassigned_domain_refused_at_submit() {
    let a = flaky_shard("doma");
    let specs = parse_shard_specs(&a.to_string()).unwrap();
    let (mut fabric, _store) =
        ShardedFabric::connect(&specs, test_cfg(), health_cfg()).unwrap();
    let q = Tensor::f32(&[1, 4, 16], vec![0.25; 64]);
    let plan = SharedGroupPlan {
        domain: "nowhere".to_string(),
        rows: vec![0],
        q_pos: vec![10],
        sets: vec![vec![]],
        calls: vec![],
        pairs: 0,
        reads: 0,
    };
    let err = fabric.submit(0, &[(&q, &plan)]).unwrap_err();
    assert!(format!("{err:#}").contains("no shard serves"), "{err:#}");
}
