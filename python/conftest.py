"""Make `compile.*` importable regardless of pytest's invocation cwd
(repo root `pytest python/tests/` or `cd python && pytest tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
