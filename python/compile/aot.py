"""AOT compile path: lower every L2/L1 graph to HLO *text* artifacts.

Run once by `make artifacts` (python is never on the request path):

    cd python && python -m compile.aot --out ../artifacts

Emits (DESIGN.md §3):
    artifacts/hlo/<name>.hlo.txt      one per (op, batch-bucket)
    artifacts/manifest.json           artifact registry (shapes, dtypes)
    artifacts/weights/tiny.bin(+json) moska-tiny weights (runtime inputs)
    artifacts/shared/<domain>.bin     precomputed Domain Shared KV stores
    artifacts/golden/*.json           reference vectors for rust tests

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the rust `xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import functools
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import binio, model, weights as weights_mod
from .configs import ARTIFACTS, DOMAINS, TINY
from .corpus import domain_tokens
from .kernels import chunk_attn, merge2, ref, router_score
from .sharedkv import build_domain

F32, I32 = jnp.float32, jnp.int32


def to_hlo_text(lowered) -> str:
    """jax lowering → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_specs():
    """Yield (name, fn, [(arg_name, shape, dtype)...]) for every artifact."""
    cfg, a = TINY, ARTIFACTS
    d, h, hkv, dh, v, f = (
        cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
        cfg.vocab, cfg.ffn_dim,
    )
    c = a.chunk
    out = []
    for b in a.batch_buckets:
        out.append((
            f"embed_b{b}", model.embed_fn,
            [("tokens", (b,), I32), ("emb", (v, d), F32)],
        ))
        out.append((
            f"qkv_b{b}", functools.partial(model.qkv_fn, cfg),
            [
                ("x", (b, d), F32), ("attn_norm", (d,), F32),
                ("wq", (d, h * dh), F32), ("wk", (d, hkv * dh), F32),
                ("wv", (d, hkv * dh), F32), ("pos", (b,), I32),
            ],
        ))
        for ct in a.attn_token_buckets:
            out.append((
                f"chunk_attn_b{b}_c{ct}", model.chunk_attn_fn,
                [
                    ("q", (b, h, dh), F32), ("k", (ct, hkv, dh), F32),
                    ("v", (ct, hkv, dh), F32), ("q_pos", (b,), I32),
                    ("k_base", (1,), I32), ("valid", (1,), I32),
                ],
            ))
        out.append((
            f"post_b{b}", functools.partial(model.post_fn, cfg),
            [
                ("attn_o", (b, h, dh), F32), ("x", (b, d), F32),
                ("wo", (h * dh, d), F32), ("ffn_norm", (d,), F32),
                ("w1", (d, f), F32), ("w3", (d, f), F32),
                ("w2", (f, d), F32),
            ],
        ))
        out.append((
            f"lm_head_b{b}", functools.partial(model.lm_head_fn, cfg),
            [
                ("x", (b, d), F32), ("final_norm", (d,), F32),
                ("w_lm", (d, v), F32),
            ],
        ))
        out.append((
            f"merge2_b{b}",
            lambda o1, m1, l1, o2, m2, l2: tuple(
                merge2(o1, m1, l1, o2, m2, l2, interpret=True)
            ),
            [
                ("o1", (b, h, dh), F32), ("m1", (b, h), F32),
                ("l1", (b, h), F32), ("o2", (b, h, dh), F32),
                ("m2", (b, h), F32), ("l2", (b, h), F32),
            ],
        ))
        for nc in a.router_chunk_buckets:
            out.append((
                f"router_b{b}_c{nc}",
                lambda q, embs: (router_score(q, embs, interpret=True),),
                [("q", (b, h, dh), F32), ("embs", (nc, hkv, dh), F32)],
            ))
    return out


def lower_all(out_dir: str) -> list:
    """Lower every artifact; return manifest entries."""
    hlo_dir = os.path.join(out_dir, "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    entries = []
    for name, fn, args in artifact_specs():
        t0 = time.time()
        in_specs = [spec(s, dt) for (_, s, dt) in args]
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"hlo/{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *in_specs)
        entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": [
                    {
                        "name": an,
                        "dtype": "i32" if dt == I32 else "f32",
                        "shape": list(s),
                    }
                    for (an, s, dt) in args
                ],
                "outputs": [
                    {
                        "dtype": "i32" if o.dtype == np.int32 else "f32",
                        "shape": list(o.shape),
                    }
                    for o in outs
                ],
            }
        )
        print(f"  lowered {name:<22} {len(text)/1024:8.1f} KiB "
              f"({time.time() - t0:.2f}s)")
    return entries


def write_goldens(out_dir: str, w: dict) -> None:
    """Reference vectors for the rust test suite (DESIGN.md §3 goldens)."""
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    cfg, a = TINY, ARTIFACTS
    rng = np.random.default_rng(a.golden_seed)

    # -- kernel-level golden: chunk_attn + router + merge on random inputs.
    b, c = 4, a.chunk
    q = rng.standard_normal((b, cfg.n_heads, cfg.head_dim)).astype(np.float32)
    k = rng.standard_normal((c, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32)
    v = rng.standard_normal((c, cfg.n_kv_heads, cfg.head_dim)).astype(np.float32)
    q_pos = np.array([100, 130, 64, -1], dtype=np.int32)
    k_base = np.array([64], dtype=np.int32)
    valid = np.array([c], dtype=np.int32)
    o, m, l = ref.chunk_attn_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        jnp.asarray(q_pos), jnp.asarray(k_base), jnp.asarray(valid),
    )
    embs = rng.standard_normal(
        (16, cfg.n_kv_heads, cfg.head_dim)
    ).astype(np.float32)
    scores = ref.router_score_ref(jnp.asarray(q), jnp.asarray(embs))

    def flat(x):
        arr = np.asarray(x, dtype=np.float32)
        # JSON has no -inf literal; the rust loader maps this sentinel back.
        arr = np.where(np.isneginf(arr), -3.0e38, arr)
        return [float(t) for t in arr.reshape(-1)]

    with open(os.path.join(gdir, "kernels.json"), "w") as f:
        json.dump(
            {
                "chunk_attn": {
                    "q": flat(q), "k": flat(k), "v": flat(v),
                    "q_pos": [int(t) for t in q_pos],
                    "k_base": int(k_base[0]), "valid": int(valid[0]),
                    "o": flat(o), "m": flat(m), "l": flat(l),
                },
                "router": {
                    "q": flat(q), "embs": flat(embs), "scores": flat(scores),
                },
            },
            f,
        )

    # -- engine-level golden: greedy decode, prompt only.
    prompt = [int(t) for t in rng.integers(0, cfg.vocab, size=12)]
    toks, logits = model.decode_greedy_ref(cfg, w, prompt, 4)
    with open(os.path.join(gdir, "decode_prompt.json"), "w") as f:
        json.dump(
            {
                "prompt": prompt,
                "tokens": toks,
                "logits": [flat(x) for x in logits],
            },
            f,
        )

    # -- engine-level golden: greedy decode over a shared domain context.
    dom = next(d for d in DOMAINS if d.name == "code")
    shared = [int(t) for t in domain_tokens(dom, cfg.vocab)]
    prompt2 = [int(t) for t in rng.integers(0, cfg.vocab, size=9)]
    toks2, logits2 = model.decode_greedy_ref(cfg, w, shared + prompt2, 4)
    with open(os.path.join(gdir, "decode_shared.json"), "w") as f:
        json.dump(
            {
                "domain": dom.name,
                "shared_tokens": dom.tokens,
                "prompt": prompt2,
                "tokens": toks2,
                "logits": [flat(x) for x in logits2],
            },
            f,
        )
    print(f"  goldens: kernels.json decode_prompt.json decode_shared.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-shared", action="store_true",
                    help="skip domain KV precompute (fast iteration)")
    ap.add_argument("--skip-golden", action="store_true")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    print("== weights ==")
    w = weights_mod.generate(TINY, ARTIFACTS.weight_seed)
    binio.save_store(os.path.join(out, "weights", "tiny.bin"), w)
    wj = {k: list(v.shape) for k, v in w.items()}
    n_params = sum(int(np.prod(s)) for s in wj.values())
    print(f"  {len(w)} tensors, {n_params} params")

    print("== HLO artifacts ==")
    entries = lower_all(out)

    print("== shared domain KV stores ==")
    domains_meta = []
    if not args.skip_shared:
        for spec_ in DOMAINS:
            store = build_domain(TINY, w, spec_)
            binio.save_store(
                os.path.join(out, "shared", f"{spec_.name}.bin"), store
            )
            nc = spec_.tokens // ARTIFACTS.chunk
            domains_meta.append(
                {"name": spec_.name, "tokens": spec_.tokens, "chunks": nc,
                 "file": f"shared/{spec_.name}.bin"}
            )
            print(f"  {spec_.name}: {spec_.tokens} tokens, {nc} chunks")

    print("== goldens ==")
    if not args.skip_golden:
        write_goldens(out, w)

    manifest = {
        "model": {
            "vocab": TINY.vocab, "d_model": TINY.d_model,
            "n_layers": TINY.n_layers, "n_heads": TINY.n_heads,
            "n_kv_heads": TINY.n_kv_heads, "head_dim": TINY.head_dim,
            "ffn_dim": TINY.ffn_dim, "rope_theta": TINY.rope_theta,
            "rms_eps": TINY.rms_eps,
        },
        "chunk": ARTIFACTS.chunk,
        "batch_buckets": list(ARTIFACTS.batch_buckets),
        "router_chunk_buckets": list(ARTIFACTS.router_chunk_buckets),
        "attn_token_buckets": list(ARTIFACTS.attn_token_buckets),
        "weights": "weights/tiny.bin",
        "domains": domains_meta,
        "artifacts": entries,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"== manifest: {len(entries)} artifacts ==")


if __name__ == "__main__":
    main()
