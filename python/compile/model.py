"""L2: the moska-tiny JAX compute graph (DESIGN.md §3).

These functions are the bodies of the AOT artifacts (`aot.py` lowers each at
every batch bucket) and double as the pure-JAX reference implementation used
to generate golden vectors and precompute shared domain KV stores. Weights
are runtime arguments, never baked constants, so one artifact serves every
layer.

The decode step is deliberately split into embed / qkv / chunk_attn / post /
lm_head artifacts: the rust coordinator owns the loop between them, which is
what lets it route queries, form Shared-KV GEMM batches across requests, and
place unique vs shared work on different nodes (paper §III.C).
"""

import jax
import jax.numpy as jnp

from .configs import TinyConfig
from .kernels import chunk_attn, ref


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-5):
    """RMSNorm over the last axis."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope(x, pos, theta=10000.0):
    """Rotary embedding, half-split convention.

    x: f32[B, n_heads, dh], pos: i32[B] (negative = padding row; the
    rotation is still applied — masking happens in attention).
    """
    b, n, dh = x.shape
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None, None] * freqs[None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# --------------------------------------------------------------------------
# artifact bodies (each lowered per batch bucket by aot.py)
# --------------------------------------------------------------------------

def embed_fn(tokens, emb):
    """tokens i32[B], emb f32[V,d] → x f32[B,d]."""
    return (jnp.take(emb, tokens, axis=0),)


def qkv_fn(cfg: TinyConfig, x, attn_norm, wq, wk, wv, pos):
    """Pre-norm + QKV projection + RoPE.

    x f32[B,d] → q f32[B,H,dh], k f32[B,Hkv,dh], v f32[B,Hkv,dh].
    """
    b = x.shape[0]
    xn = rms_norm(x, attn_norm, cfg.rms_eps)
    q = (xn @ wq).reshape(b, cfg.n_heads, cfg.head_dim)
    k = (xn @ wk).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    v = (xn @ wv).reshape(b, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def chunk_attn_fn(q, k, v, q_pos, k_base, valid):
    """The Pallas Shared-KV GEMM attention kernel, lowered in-graph."""
    return tuple(chunk_attn(q, k, v, q_pos, k_base, valid, interpret=True))


def post_fn(cfg: TinyConfig, attn_o, x, wo, ffn_norm, w1, w3, w2):
    """Attention out-proj + residual + SwiGLU FFN + residual.

    attn_o f32[B,H,dh] (already normalized), x f32[B,d] → x' f32[B,d].
    """
    b = x.shape[0]
    h = x + attn_o.reshape(b, cfg.q_dim) @ wo
    hn = rms_norm(h, ffn_norm, cfg.rms_eps)
    ffn = (jax.nn.silu(hn @ w1) * (hn @ w3)) @ w2
    return (h + ffn,)


def lm_head_fn(cfg: TinyConfig, x, final_norm, w_lm):
    """Final norm + LM head. x f32[B,d] → logits f32[B,V]."""
    return (rms_norm(x, final_norm, cfg.rms_eps) @ w_lm,)


# --------------------------------------------------------------------------
# full-model reference (golden generation, shared-KV precompute, tests)
# --------------------------------------------------------------------------

def layer_weights(weights: dict, i: int):
    lw = weights
    return (
        lw[f"layer{i}.attn_norm"], lw[f"layer{i}.wq"], lw[f"layer{i}.wk"],
        lw[f"layer{i}.wv"], lw[f"layer{i}.wo"], lw[f"layer{i}.ffn_norm"],
        lw[f"layer{i}.w1"], lw[f"layer{i}.w3"], lw[f"layer{i}.w2"],
    )


def forward_ref(cfg: TinyConfig, weights: dict, tokens, pos, caches=None,
                block=256):
    """Token-parallel forward over `tokens` i32[T] at positions `pos` i32[T].

    `caches`: optional list per layer of (k f32[S,Hkv,dh], v, k_pos i32[S])
    of already-prefilled context the new tokens attend to (in addition to
    themselves, causally).

    Returns (logits f32[T,V], new_caches) where new_caches appends the new
    K/V. Queries are processed in `block`-sized slabs to bound memory on
    multi-thousand-token prefills.
    """
    t = tokens.shape[0]
    x = embed_fn(tokens, weights["embed"])[0]
    new_caches = []
    for i in range(cfg.n_layers):
        an, wq, wk, wv, wo, fn_, w1, w3, w2 = layer_weights(weights, i)
        q, k, v = qkv_fn(cfg, x, an, wq, wk, wv, pos)
        if caches is not None and caches[i] is not None:
            pk, pv, ppos = caches[i]
            k_all = jnp.concatenate([pk, k], axis=0)
            v_all = jnp.concatenate([pv, v], axis=0)
            kp_all = jnp.concatenate([ppos, pos], axis=0)
        else:
            k_all, v_all, kp_all = k, v, pos
        outs = []
        for s in range(0, t, block):
            e = min(s + block, t)
            outs.append(
                ref.full_attn_ref(q[s:e], k_all, v_all, pos[s:e], kp_all)
            )
        attn_o = jnp.concatenate(outs, axis=0)
        x = post_fn(cfg, attn_o, x, wo, fn_, w1, w3, w2)[0]
        new_caches.append((k_all, v_all, kp_all))
    logits = lm_head_fn(cfg, x, weights["final_norm"], weights["lm_head"])[0]
    return logits, new_caches


def prefill_kv(cfg: TinyConfig, weights: dict, tokens, base_pos=0):
    """Prefill `tokens` i32[T]; return per-layer (k, v) f32[T,Hkv,dh].

    Used by `sharedkv.py` to build the persistent Domain-Specific Shared KV
    Caches the rust engine serves from.
    """
    pos = jnp.arange(tokens.shape[0], dtype=jnp.int32) + base_pos
    _, caches = forward_ref(cfg, weights, tokens, pos)
    return [(k, v) for (k, v, _) in caches]


def decode_greedy_ref(cfg: TinyConfig, weights: dict, prompt, n_steps):
    """Greedy decode reference: returns (tokens_out, per-step logits list).

    The golden vectors for the rust engine integration test come from here.
    """
    tokens = jnp.asarray(prompt, dtype=jnp.int32)
    pos = jnp.arange(tokens.shape[0], dtype=jnp.int32)
    logits, caches = forward_ref(cfg, weights, tokens, pos)
    out_tokens = []
    step_logits = []
    cur = int(jnp.argmax(logits[-1]))
    cur_pos = tokens.shape[0]
    step_logits.append(logits[-1])
    out_tokens.append(cur)
    for _ in range(n_steps - 1):
        tok = jnp.asarray([cur], dtype=jnp.int32)
        p = jnp.asarray([cur_pos], dtype=jnp.int32)
        logits, caches = forward_ref(cfg, weights, tok, p, caches)
        cur = int(jnp.argmax(logits[-1]))
        cur_pos += 1
        step_logits.append(logits[-1])
        out_tokens.append(cur)
    return out_tokens, step_logits
