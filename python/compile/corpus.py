"""Synthetic domain corpora (DESIGN.md §6 substitutions).

Stands in for the paper's persistent domain knowledge bases (laws, medical
cases, boilerplate code). Router/batcher/cache behaviour depends only on
chunk identity and reuse statistics, so deterministic synthetic token
streams preserve the evaluated behaviour. Streams are structured (repeated
motifs + noise) rather than iid-uniform so chunk embeddings are
distinguishable and routing is non-degenerate.
"""

import numpy as np

from .configs import DomainSpec


def domain_tokens(spec: DomainSpec, vocab: int) -> np.ndarray:
    """Deterministic token stream for a domain: motif-structured bytes.

    The stream interleaves a small set of domain 'motifs' (think: recurring
    legal clauses) with noise tokens, giving chunks distinct, stable
    embedding signatures.
    """
    rng = np.random.default_rng(spec.seed)
    n_motifs = 8
    motif_len = 32
    motifs = rng.integers(0, vocab, size=(n_motifs, motif_len), dtype=np.int64)
    out = np.empty(spec.tokens, dtype=np.int32)
    i = 0
    while i < spec.tokens:
        if rng.random() < 0.7:
            m = motifs[rng.integers(0, n_motifs)]
            n = min(motif_len, spec.tokens - i)
            out[i : i + n] = m[:n]
            i += n
        else:
            n = min(int(rng.integers(4, 16)), spec.tokens - i)
            out[i : i + n] = rng.integers(0, vocab, size=n)
            i += n
    return out
