"""Model + artifact configuration shared across the compile path.

`moska-tiny` is the laptop-scale Llama-style substrate (DESIGN.md §3): the
live serving system runs this model through AOT-compiled XLA artifacts. The
paper's Llama-3.1-8B shapes live in the rust analytical model, not here.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class TinyConfig:
    """moska-tiny architecture (GQA + RoPE + SwiGLU, f32)."""

    vocab: int = 256          # byte-level tokenizer
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4          # query heads
    n_kv_heads: int = 2       # GQA key/value heads
    head_dim: int = 16
    ffn_dim: int = 192
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclass(frozen=True)
class ArtifactConfig:
    """Static-shape bucketing for the AOT artifacts (DESIGN.md §3)."""

    chunk: int = 64                                   # tokens per KV chunk
    batch_buckets: tuple = (1, 2, 4, 8, 16, 32)       # live-batch buckets
    router_chunk_buckets: tuple = (16, 64, 256)       # routed chunk counts
    # chunk_attn token buckets: the coordinator coalesces runs of
    # consecutive chunks into one kernel call (§Perf opt 2) — these are
    # the compiled K/V lengths it can target.
    attn_token_buckets: tuple = (64, 256, 1024)
    weight_seed: int = 42
    golden_seed: int = 1234


@dataclass(frozen=True)
class DomainSpec:
    """A synthetic shared-context domain (DESIGN.md §6 substitutions)."""

    name: str
    tokens: int            # total shared context length (multiple of chunk)
    seed: int


TINY = TinyConfig()
ARTIFACTS = ArtifactConfig()

# Shared domain corpora: deterministic synthetic token streams standing in
# for the paper's "laws / medical cases / boilerplate code" KV libraries.
DOMAINS = (
    DomainSpec("legal", 4096, 101),
    DomainSpec("medical", 2048, 202),
    DomainSpec("code", 1024, 303),
)
