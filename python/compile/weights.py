"""Deterministic weight generation for moska-tiny.

Weights are *runtime inputs* to every HLO artifact (not baked constants), so
a single artifact per (op, batch-bucket) serves all layers; rust loads the
same store via `util/bin.rs`. Scaling follows standard fan-in init so the
synthetic model produces well-conditioned logits (goldens stay in a sane
numeric range).
"""

import numpy as np

from .configs import TinyConfig


def layer_names(i: int):
    return [
        f"layer{i}.attn_norm",
        f"layer{i}.wq",
        f"layer{i}.wk",
        f"layer{i}.wv",
        f"layer{i}.wo",
        f"layer{i}.ffn_norm",
        f"layer{i}.w1",
        f"layer{i}.w3",
        f"layer{i}.w2",
    ]


def generate(cfg: TinyConfig, seed: int) -> dict:
    """Return `{name: ndarray}` for the full model, deterministically."""
    rng = np.random.default_rng(seed)

    def mat(rows, cols):
        return (rng.standard_normal((rows, cols)) / np.sqrt(rows)).astype(
            np.float32
        )

    w = {"embed": (rng.standard_normal((cfg.vocab, cfg.d_model)) * 0.02).astype(np.float32)}
    for i in range(cfg.n_layers):
        w[f"layer{i}.attn_norm"] = np.ones(cfg.d_model, np.float32)
        w[f"layer{i}.wq"] = mat(cfg.d_model, cfg.q_dim)
        w[f"layer{i}.wk"] = mat(cfg.d_model, cfg.kv_dim)
        w[f"layer{i}.wv"] = mat(cfg.d_model, cfg.kv_dim)
        w[f"layer{i}.wo"] = mat(cfg.q_dim, cfg.d_model)
        w[f"layer{i}.ffn_norm"] = np.ones(cfg.d_model, np.float32)
        w[f"layer{i}.w1"] = mat(cfg.d_model, cfg.ffn_dim)
        w[f"layer{i}.w3"] = mat(cfg.d_model, cfg.ffn_dim)
        w[f"layer{i}.w2"] = mat(cfg.ffn_dim, cfg.d_model)
    w["final_norm"] = np.ones(cfg.d_model, np.float32)
    w["lm_head"] = mat(cfg.d_model, cfg.vocab)
    return w
