"""L1 Pallas kernel: MoE-inspired chunk router (paper §III.B).

Training-free routing exactly as MoBA/LongHeads: relevance of shared chunk c
to query b is the inner product between the query vectors and the chunk's
mean-pooled K embedding, averaged over query heads. Top-k selection happens
on the rust side (`router/topk.rs`) because k is a serving-time knob.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, emb_ref, out_ref, *, group: int):
    q = q_ref[...]                      # [B, H, dh]
    embs = emb_ref[...]                 # [C, Hkv, dh]
    b, h, dh = q.shape
    c, hkv, _ = embs.shape
    qg = q.reshape(b, hkv, group, dh)
    s = jnp.einsum(
        "bkgd,ckd->bkgc", qg, embs, preferred_element_type=jnp.float32
    )
    out_ref[...] = jnp.mean(s.reshape(b, h, c), axis=1).astype(jnp.float32)


def router_score(q, embs, *, interpret=True):
    """q f32[B,H,dh] × embs f32[C,Hkv,dh] → scores f32[B,C]."""
    b, h, dh = q.shape
    c, hkv, _ = embs.shape
    assert h % hkv == 0
    kern = functools.partial(_kernel, group=h // hkv)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(q, embs)
