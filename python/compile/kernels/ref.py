"""Pure-jnp correctness oracles for every L1 kernel (DESIGN.md §3).

These are the ground truth the Pallas kernels, the AOT artifacts, and the
rust native fallback are all tested against. The chunked-attention algebra
(unnormalized partials + log-sum-exp merge) is the flash-attention
decomposition: attention over a union of chunks equals the LSE-merge of
per-chunk partials — `test_kernel.py::test_chunked_equals_full` asserts it.

Partial convention (per query row, per head):
    m = max_j score_j           (-inf if every key is masked)
    l = sum_j exp(score_j - m)  (0 if every key is masked)
    o = sum_j exp(score_j - m) * v_j        (UNnormalized)
Final output after merging all partials: o / l.
"""

import jax.numpy as jnp

NEG_INF = float("-inf")


def chunk_attn_ref(q, k, v, q_pos, k_base, valid):
    """Shared-KV chunk attention oracle.

    q:      f32[B, H, dh]   queries (B = batched concurrent requests — the
                            paper's GEMM batching dimension)
    k, v:   f32[C, Hkv, dh] one shared chunk (GQA: Hkv <= H)
    q_pos:  i32[B]          absolute position of each query; -1 = padding row
    k_base: i32[1]          absolute position of chunk token 0
    valid:  i32[1]          number of valid tokens in the chunk (<= C)
    returns (o f32[B,H,dh], m f32[B,H], l f32[B,H]) unnormalized partials.
    """
    B, H, dh = q.shape
    C, Hkv, _ = k.shape
    group = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    qg = q.reshape(B, Hkv, group, dh)
    # The Shared-KV GEMM: all B queries hit the same chunk K/V.
    scores = jnp.einsum("bkgd,ckd->bkgc", qg, k) * scale  # [B,Hkv,group,C]

    j = jnp.arange(C, dtype=jnp.int32)
    allowed = (j[None, :] < valid[0]) & (k_base[0] + j[None, :] <= q_pos[:, None])
    allowed &= q_pos[:, None] >= 0  # padding rows: fully masked
    scores = jnp.where(allowed[:, None, None, :], scores, NEG_INF)

    m = jnp.max(scores, axis=-1)  # [B,Hkv,group]; -inf if all masked
    p = jnp.where(jnp.isfinite(scores), jnp.exp(scores - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgc,ckd->bkgd", p, v)
    return (
        o.reshape(B, H, dh).astype(jnp.float32),
        m.reshape(B, H).astype(jnp.float32),
        l.reshape(B, H).astype(jnp.float32),
    )


def merge2_ref(o1, m1, l1, o2, m2, l2):
    """LSE-merge two partials into one (o, m, l)."""
    m = jnp.maximum(m1, m2)
    s1 = jnp.where(jnp.isfinite(m1), jnp.exp(m1 - m), 0.0)
    s2 = jnp.where(jnp.isfinite(m2), jnp.exp(m2 - m), 0.0)
    o = o1 * s1[..., None] + o2 * s2[..., None]
    l = l1 * s1 + l2 * s2
    return o, m, l


def merge_ref(parts):
    """LSE-merge a list of (o, m, l) partials into one (o, m, l)."""
    o, m, l = parts[0]
    for o2, m2, l2 in parts[1:]:
        o, m, l = merge2_ref(o, m, l, o2, m2, l2)
    return o, m, l


def finalize_ref(o, l):
    """Normalize merged partials; fully-masked rows produce zeros."""
    safe = jnp.where(l > 0.0, l, 1.0)
    return jnp.where((l > 0.0)[..., None], o / safe[..., None], 0.0)


def full_attn_ref(q, k, v, q_pos, k_pos):
    """Direct softmax attention over the *whole* context (no chunking).

    q: f32[B,H,dh]; k, v: f32[T,Hkv,dh]; q_pos i32[B]; k_pos i32[T].
    Causal: key j visible to query b iff k_pos[j] <= q_pos[b].
    """
    B, H, dh = q.shape
    T, Hkv, _ = k.shape
    group = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    qg = q.reshape(B, Hkv, group, dh)
    scores = jnp.einsum("bkgd,tkd->bkgt", qg, k) * scale
    allowed = (k_pos[None, :] <= q_pos[:, None]) & (q_pos[:, None] >= 0)
    scores = jnp.where(allowed[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(jnp.isfinite(scores), jnp.exp(scores - m), 0.0)
    l = jnp.sum(p, axis=-1)  # [B,Hkv,group]
    o = jnp.einsum("bkgt,tkd->bkgd", p, v)
    safe = jnp.where(l > 0.0, l, 1.0)
    o = jnp.where((l > 0.0)[..., None], o / safe[..., None], 0.0)
    return o.reshape(B, H, dh)


def router_score_ref(q, embs):
    """MoE-inspired chunk-router oracle (MoBA/LongHeads scheme).

    q:    f32[B, H, dh]     current queries
    embs: f32[C, Hkv, dh]   mean-pooled-K chunk embeddings
    returns f32[B, C]: mean over query heads of q_h . emb_{c, kv(h)}.
    """
    B, H, dh = q.shape
    C, Hkv, _ = embs.shape
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, dh)
    s = jnp.einsum("bkgd,ckd->bkgc", qg, embs)  # [B,Hkv,group,C]
    return jnp.mean(s.reshape(B, Hkv * group, C), axis=1)
