"""L1 Pallas kernel: pairwise LSE merge of attention partials.

Combines two unnormalized chunk partials (o, m, l) into one — the
flash-attention combine step. The rust coordinator merges arbitrary arity
natively (`attention/merge.rs`, same algebra); this kernel is the in-graph
variant used when the merge is fused into an artifact, and the oracle for
both lives in `ref.merge2_ref`.

The -inf bookkeeping matters: a fully-masked partial has (m=-inf, l=0) and
must behave as the merge identity — `where(isfinite(m), exp(m-m*), 0)`
avoids the `exp(-inf - -inf) = nan` trap.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(o1_ref, m1_ref, l1_ref, o2_ref, m2_ref, l2_ref,
            o_ref, m_ref, l_ref):
    m1, m2 = m1_ref[...], m2_ref[...]
    m = jnp.maximum(m1, m2)
    s1 = jnp.where(jnp.isfinite(m1), jnp.exp(m1 - m), 0.0)
    s2 = jnp.where(jnp.isfinite(m2), jnp.exp(m2 - m), 0.0)
    o_ref[...] = o1_ref[...] * s1[..., None] + o2_ref[...] * s2[..., None]
    l_ref[...] = l1_ref[...] * s1 + l2_ref[...] * s2
    m_ref[...] = m


def merge2(o1, m1, l1, o2, m2, l2, *, interpret=True):
    """Merge two (o f32[B,H,dh], m f32[B,H], l f32[B,H]) partials."""
    b, h, dh = o1.shape
    return pl.pallas_call(
        _kernel,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(o1, m1, l1, o2, m2, l2)
