"""L1 Pallas kernels + pure-jnp oracles (see each module's docstring)."""

from .chunk_attn import chunk_attn
from .merge import merge2
from .router import router_score
from . import ref

__all__ = ["chunk_attn", "merge2", "router_score", "ref"]
