"""L1 Pallas kernel: Shared-KV chunk attention (the paper's §III.A hot spot).

One call computes the attention partials of B concurrent queries against ONE
shared KV chunk. B is the paper's batching dimension: instead of B
memory-bound GEMVs (one per request), the chunk's K/V tile is loaded once
and all B queries stream through a single GEMM — arithmetic intensity grows
linearly with B, which is exactly the Fig 1(b)/Fig 4 bandwidth argument.

TPU adaptation (DESIGN.md §Hardware-Adaptation): BlockSpec keeps the chunk's
K/V resident (one HBM→VMEM load per grid row) while the grid walks query
tiles; the two einsums lower to `dot_general`, i.e. MXU work on real
hardware. On this image the kernel must run `interpret=True` (CPU PJRT
cannot execute Mosaic custom-calls), so correctness is validated here and
structure (VMEM footprint / reuse factor) is analyzed statically in
EXPERIMENTS.md §Perf.

Masking unifies every attention call in the system:
  * `valid`  — number of real tokens in the chunk (tail chunks).
  * `q_pos`/`k_base` — absolute positions; key j is visible iff
    `k_base + j <= q_pos[b]` (causality, incl. chunked prefill).
  * `q_pos[b] < 0` — padding row (batch-bucket padding): fully masked,
    produces (o=0, m=-inf, l=0) which is the LSE-merge identity.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

# Query-tile height. 8 rows keeps the padded-lane waste bounded for the
# small buckets while still tiling the big ones; see §Perf for the sweep.
Q_TILE = 8


def _kernel(q_ref, qpos_ref, k_ref, v_ref, kbase_ref, valid_ref,
            o_ref, m_ref, l_ref, *, group: int):
    """One grid step: a (Q_TILE, H, dh) query tile vs the whole chunk."""
    q = q_ref[...]                      # [T, H, dh]
    k = k_ref[...]                      # [C, Hkv, dh]
    v = v_ref[...]
    q_pos = qpos_ref[...]               # [T] i32
    k_base = kbase_ref[0]
    valid = valid_ref[0]

    t, h, dh = q.shape
    c, hkv, _ = k.shape
    qg = q.reshape(t, hkv, group, dh)
    scale = (1.0 / jnp.sqrt(jnp.float32(dh))).astype(jnp.float32)

    # The Shared-KV GEMM (MXU dot on real TPU): K loaded once for all rows.
    scores = jnp.einsum(
        "bkgd,ckd->bkgc", qg, k, preferred_element_type=jnp.float32
    ) * scale                           # [T, Hkv, group, C]

    j = jax.lax.broadcasted_iota(jnp.int32, (t, c), 1)
    allowed = (j < valid) & (k_base + j <= q_pos[:, None]) & (
        q_pos[:, None] >= 0
    )
    scores = jnp.where(allowed[:, None, None, :], scores, NEG_INF)

    m = jnp.max(scores, axis=-1)        # [T, Hkv, group]
    p = jnp.where(jnp.isfinite(scores), jnp.exp(scores - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum(
        "bkgc,ckd->bkgd", p, v, preferred_element_type=jnp.float32
    )

    o_ref[...] = o.reshape(t, h, dh).astype(jnp.float32)
    m_ref[...] = m.reshape(t, h).astype(jnp.float32)
    l_ref[...] = l.reshape(t, h).astype(jnp.float32)


def chunk_attn(q, k, v, q_pos, k_base, valid, *, interpret=True):
    """Pallas Shared-KV chunk attention; signature mirrors `ref.chunk_attn_ref`.

    q f32[B,H,dh], k/v f32[C,Hkv,dh], q_pos i32[B], k_base i32[1],
    valid i32[1] → (o f32[B,H,dh], m f32[B,H], l f32[B,H]).
    """
    b, h, dh = q.shape
    c, hkv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    t = min(b, Q_TILE)
    assert b % t == 0, f"batch {b} not divisible by query tile {t}"
    grid = (b // t,)

    kern = functools.partial(_kernel, group=group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, h, dh), lambda i: (i, 0, 0)),       # q tile
            pl.BlockSpec((t,), lambda i: (i,)),                  # q_pos tile
            pl.BlockSpec((c, hkv, dh), lambda i: (0, 0, 0)),     # K: resident
            pl.BlockSpec((c, hkv, dh), lambda i: (0, 0, 0)),     # V: resident
            pl.BlockSpec((1,), lambda i: (0,)),                  # k_base
            pl.BlockSpec((1,), lambda i: (0,)),                  # valid
        ],
        out_specs=[
            pl.BlockSpec((t, h, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((t, h), lambda i: (i, 0)),
            pl.BlockSpec((t, h), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(q, q_pos, k, v, k_base, valid)
