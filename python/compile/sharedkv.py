"""Build-time precompute of Domain-Specific Shared KV Caches (paper §III.A).

For each synthetic domain corpus the tiny model is prefilled once and the
resulting per-layer K/V tensors are chunked (CHUNK tokens each) and dumped,
together with mean-pooled-K chunk embeddings (the router's 'expert'
signatures, §III.B). The rust shared chunk store (`kvcache/shared_store.rs`)
loads these as the persistent, massively-reused shared context.

Store layout per domain (binio container, see `binio.py`):
    tokens                 i32[T]
    layer{i}.k             f32[nc, CHUNK, Hkv, dh]
    layer{i}.v             f32[nc, CHUNK, Hkv, dh]
    layer{i}.emb           f32[nc, Hkv, dh]     (post-RoPE K mean)
"""

import numpy as np

from .configs import ARTIFACTS, TinyConfig, DomainSpec
from .corpus import domain_tokens
from .model import prefill_kv


def build_domain(cfg: TinyConfig, weights: dict, spec: DomainSpec) -> dict:
    """Prefill one domain corpus; return the binio tensor dict."""
    chunk = ARTIFACTS.chunk
    toks = domain_tokens(spec, cfg.vocab)
    assert toks.shape[0] % chunk == 0, (spec.name, toks.shape)
    nc = toks.shape[0] // chunk

    import jax.numpy as jnp

    kv = prefill_kv(cfg, weights, jnp.asarray(toks))
    out = {"tokens": toks.astype(np.int32)}
    for i, (k, v) in enumerate(kv):
        k = np.asarray(k, dtype=np.float32)
        v = np.asarray(v, dtype=np.float32)
        kc = k.reshape(nc, chunk, cfg.n_kv_heads, cfg.head_dim)
        vc = v.reshape(nc, chunk, cfg.n_kv_heads, cfg.head_dim)
        out[f"layer{i}.k"] = kc
        out[f"layer{i}.v"] = vc
        out[f"layer{i}.emb"] = kc.mean(axis=1)
    return out
