"""Binary tensor container shared with the rust side (`util/bin.rs`).

Layout: one raw little-endian `.bin` blob + a sibling `.json` manifest:

    {"tensors": [{"name": str, "dtype": "f32"|"i32",
                  "shape": [int...], "offset": int_bytes}]}

Tensors are stored back-to-back in manifest order, row-major, no padding.
"""

import json
import os

import numpy as np

_DTYPES = {"f32": np.float32, "i32": np.int32}
_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32"}


def save_store(path_bin: str, tensors: dict) -> None:
    """Write `{name: ndarray}` to `path_bin` + `path_bin[:-4] + '.json'`."""
    assert path_bin.endswith(".bin"), path_bin
    os.makedirs(os.path.dirname(path_bin), exist_ok=True)
    manifest = []
    offset = 0
    with open(path_bin, "wb") as f:
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _NAMES:
                raise ValueError(f"unsupported dtype {arr.dtype} for {name}")
            data = arr.tobytes()
            manifest.append(
                {
                    "name": name,
                    "dtype": _NAMES[arr.dtype],
                    "shape": list(arr.shape),
                    "offset": offset,
                }
            )
            f.write(data)
            offset += len(data)
    with open(path_bin[:-4] + ".json", "w") as f:
        json.dump({"tensors": manifest}, f, indent=1)


def load_store(path_bin: str) -> dict:
    """Read a store written by `save_store` back into `{name: ndarray}`."""
    with open(path_bin[:-4] + ".json") as f:
        manifest = json.load(f)["tensors"]
    out = {}
    with open(path_bin, "rb") as f:
        blob = f.read()
    for ent in manifest:
        dt = _DTYPES[ent["dtype"]]
        n = int(np.prod(ent["shape"])) if ent["shape"] else 1
        start = ent["offset"]
        arr = np.frombuffer(blob, dtype=dt, count=n, offset=start)
        out[ent["name"]] = arr.reshape(ent["shape"]).copy()
    return out
