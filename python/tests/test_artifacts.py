"""Artifact integrity: manifest ⇄ files ⇄ shapes, binio round-trip, domains.

Runs against the `artifacts/` tree produced by `make artifacts`; skips
cleanly when it has not been built yet (fresh checkout).
"""

import json
import os

import numpy as np
import pytest

from compile import binio, weights as weights_mod
from compile.configs import ARTIFACTS, DOMAINS, TINY
from compile.corpus import domain_tokens

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def test_binio_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.standard_normal((3, 4)).astype(np.float32),
        "b": rng.integers(0, 100, size=(7,)).astype(np.int32),
        "c.nested/name": rng.standard_normal((2, 2, 2)).astype(np.float32),
    }
    path = str(tmp_path / "store.bin")
    binio.save_store(path, tensors)
    back = binio.load_store(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_binio_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(ValueError):
        binio.save_store(
            str(tmp_path / "bad.bin"), {"x": np.zeros(3, np.float64)}
        )


def test_corpus_deterministic_and_in_vocab():
    for spec in DOMAINS:
        t1 = domain_tokens(spec, TINY.vocab)
        t2 = domain_tokens(spec, TINY.vocab)
        np.testing.assert_array_equal(t1, t2)
        assert t1.shape[0] == spec.tokens
        assert t1.min() >= 0 and t1.max() < TINY.vocab
        assert t1.shape[0] % ARTIFACTS.chunk == 0


def test_corpus_domains_differ():
    a = domain_tokens(DOMAINS[0], TINY.vocab)
    b = domain_tokens(DOMAINS[1], TINY.vocab)
    assert not np.array_equal(a[: DOMAINS[1].tokens], b)


@needs_artifacts
def test_manifest_files_exist():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["chunk"] == ARTIFACTS.chunk
    assert man["batch_buckets"] == list(ARTIFACTS.batch_buckets)
    for ent in man["artifacts"]:
        path = os.path.join(ART, ent["file"])
        assert os.path.exists(path), ent["name"]
        assert os.path.getsize(path) > 0
    # every bucket × op present
    names = {e["name"] for e in man["artifacts"]}
    for b in man["batch_buckets"]:
        for op in ("embed", "qkv", "post", "lm_head", "merge2"):
            assert f"{op}_b{b}" in names
        for c in man["router_chunk_buckets"]:
            assert f"router_b{b}_c{c}" in names
        for ct in man["attn_token_buckets"]:
            assert f"chunk_attn_b{b}_c{ct}" in names


@needs_artifacts
def test_weights_store_matches_generator():
    w_disk = binio.load_store(os.path.join(ART, "weights", "tiny.bin"))
    w_gen = weights_mod.generate(TINY, ARTIFACTS.weight_seed)
    assert set(w_disk) == set(w_gen)
    for k in w_gen:
        np.testing.assert_array_equal(w_disk[k], w_gen[k])


@needs_artifacts
def test_domain_stores_shapes():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for dom in man["domains"]:
        store = binio.load_store(os.path.join(ART, dom["file"]))
        nc = dom["chunks"]
        assert store["tokens"].shape == (dom["tokens"],)
        for i in range(TINY.n_layers):
            assert store[f"layer{i}.k"].shape == (
                nc, ARTIFACTS.chunk, TINY.n_kv_heads, TINY.head_dim
            )
            assert store[f"layer{i}.v"].shape == store[f"layer{i}.k"].shape
            assert store[f"layer{i}.emb"].shape == (
                nc, TINY.n_kv_heads, TINY.head_dim
            )
            # embeddings really are the chunk K-means
            np.testing.assert_allclose(
                store[f"layer{i}.emb"],
                store[f"layer{i}.k"].mean(axis=1),
                rtol=1e-5, atol=1e-6,
            )


@needs_artifacts
def test_goldens_exist_and_finite():
    gdir = os.path.join(ART, "golden")
    for name in ("kernels.json", "decode_prompt.json", "decode_shared.json"):
        with open(os.path.join(gdir, name)) as f:
            data = json.load(f)
        assert data
    with open(os.path.join(gdir, "decode_prompt.json")) as f:
        g = json.load(f)
    assert len(g["tokens"]) == len(g["logits"])
    for row in g["logits"]:
        assert len(row) == TINY.vocab
        assert all(abs(x) < 1e30 for x in row)


@needs_artifacts
def test_hlo_text_parses_structurally():
    """HLO text artifacts look like HLO modules (ENTRY + parameters)."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for ent in man["artifacts"][:8]:
        with open(os.path.join(ART, ent["file"])) as f:
            text = f.read()
        assert "ENTRY" in text
        for i in range(len(ent["inputs"])):
            assert f"parameter({i})" in text, (ent["name"], i)
