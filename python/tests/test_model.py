"""L2 model correctness: moska-tiny graph bodies + the engine algorithm.

`test_engine_algorithm_in_python` is the pre-flight for the rust engine: it
re-implements the rust decode loop (embed → qkv → routed chunk_attn over
chunked caches → merge → post → lm_head) in python using the same Pallas
kernels the artifacts contain, and checks it against the monolithic
full-attention reference. If this passes and the rust goldens pass, every
layer of the stack agrees.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model, weights as weights_mod
from compile.configs import TINY, ARTIFACTS
from compile.kernels import chunk_attn, ref

CFG = TINY
W = weights_mod.generate(CFG, ARTIFACTS.weight_seed)


def test_weights_deterministic():
    w2 = weights_mod.generate(CFG, ARTIFACTS.weight_seed)
    for k in W:
        np.testing.assert_array_equal(W[k], w2[k])
    w3 = weights_mod.generate(CFG, ARTIFACTS.weight_seed + 1)
    assert not np.allclose(W["embed"], w3["embed"])


def test_rms_norm_scale_invariant_direction():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, CFG.d_model)), jnp.float32)
    w = jnp.ones(CFG.d_model, jnp.float32)
    y1 = model.rms_norm(x, w)
    y2 = model.rms_norm(x * 10.0, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)


def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, CFG.n_heads, CFG.head_dim)),
                    jnp.float32)
    pos = jnp.asarray([3, 40], jnp.int32)
    y = model.rope(x, pos, CFG.rope_theta)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5, atol=1e-5,
    )
    # relativity: <rope(q,p1), rope(k,p2)> depends only on p1 - p2.
    q = jnp.asarray(rng.standard_normal((1, 1, CFG.head_dim)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, CFG.head_dim)), jnp.float32)
    def ip(pq, pk):
        qq = model.rope(q, jnp.asarray([pq], jnp.int32))
        kk = model.rope(k, jnp.asarray([pk], jnp.int32))
        return float(jnp.sum(qq * kk))
    assert abs(ip(10, 4) - ip(106, 100)) < 1e-3


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([1, 2, 4, 8, 16, 32]), seed=st.integers(0, 2**31 - 1))
def test_qkv_shapes(b, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, CFG.d_model)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 100, size=b), jnp.int32)
    q, k, v = model.qkv_fn(CFG, x, W["layer0.attn_norm"], W["layer0.wq"],
                           W["layer0.wk"], W["layer0.wv"], pos)
    assert q.shape == (b, CFG.n_heads, CFG.head_dim)
    assert k.shape == (b, CFG.n_kv_heads, CFG.head_dim)
    assert v.shape == (b, CFG.n_kv_heads, CFG.head_dim)


def test_decode_greedy_deterministic():
    toks1, logits1 = model.decode_greedy_ref(CFG, W, [1, 2, 3, 4], 3)
    toks2, logits2 = model.decode_greedy_ref(CFG, W, [1, 2, 3, 4], 3)
    assert toks1 == toks2
    np.testing.assert_array_equal(np.asarray(logits1[0]),
                                  np.asarray(logits2[0]))


def test_logits_sane():
    logits, _ = model.forward_ref(
        CFG, W, jnp.asarray([5, 9, 200], jnp.int32),
        jnp.arange(3, dtype=jnp.int32),
    )
    a = np.asarray(logits)
    assert a.shape == (3, CFG.vocab)
    assert np.all(np.isfinite(a))
    assert np.abs(a).max() < 100.0


def _chunked_decode_step(tok, pos, caches):
    """The rust engine's decode-step algorithm, in python, on the kernels.

    caches: per layer (k [T,Hkv,dh], v, base positions are 0..T-1) stored as
    CHUNK-sized pieces exactly like the rust chunk store.
    """
    chunk = ARTIFACTS.chunk
    x = model.embed_fn(jnp.asarray([tok], jnp.int32), W["embed"])[0]
    new_caches = []
    for i in range(CFG.n_layers):
        an, wq, wk, wv, wo, fn_, w1, w3, w2 = model.layer_weights(W, i)
        p = jnp.asarray([pos], jnp.int32)
        q, k, v = model.qkv_fn(CFG, x, an, wq, wk, wv, p)
        pk, pv = caches[i]
        k_all = jnp.concatenate([pk, k], axis=0)
        v_all = jnp.concatenate([pv, v], axis=0)
        t = k_all.shape[0]
        parts = []
        for s in range(0, t, chunk):
            e = min(s + chunk, t)
            kc = k_all[s:e]
            vc = v_all[s:e]
            if e - s < chunk:  # pad tail chunk like the rust store does
                pad = chunk - (e - s)
                kc = jnp.pad(kc, ((0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(vc, ((0, pad), (0, 0), (0, 0)))
            parts.append(
                chunk_attn(q, kc, vc, p, jnp.asarray([s], jnp.int32),
                           jnp.asarray([e - s], jnp.int32))
            )
        o, m, l = ref.merge_ref(parts)
        attn_o = ref.finalize_ref(o, l)
        x = model.post_fn(CFG, attn_o, x, wo, fn_, w1, w3, w2)[0]
        new_caches.append((k_all, v_all))
    logits = model.lm_head_fn(CFG, x, W["final_norm"], W["lm_head"])[0]
    return logits[0], new_caches


def test_engine_algorithm_in_python():
    """Chunked engine decode == monolithic reference decode (logits)."""
    prompt = [17, 3, 250, 99, 4, 42, 7, 8, 150, 31]
    want_toks, want_logits = model.decode_greedy_ref(CFG, W, prompt, 3)

    # prefill via reference, then decode step-by-step through the chunked
    # engine algorithm.
    toks = jnp.asarray(prompt, jnp.int32)
    pos = jnp.arange(len(prompt), dtype=jnp.int32)
    logits, caches = model.forward_ref(CFG, W, toks, pos)
    caches = [(k, v) for (k, v, _) in caches]
    cur = int(jnp.argmax(logits[-1]))
    assert cur == want_toks[0]

    cur_pos = len(prompt)
    for step in range(1, 3):
        step_logits, caches = _chunked_decode_step(cur, cur_pos, caches)
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(want_logits[step]),
            rtol=1e-4, atol=1e-4,
        )
        cur = int(jnp.argmax(step_logits))
        cur_pos += 1
        assert cur == want_toks[step]
