"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer: the same kernels
are lowered into the AOT artifacts the rust engine executes, so allclose
here + the rust golden tests transitively validate the serving hot path.

Hypothesis sweeps shapes (batch buckets × chunk sizes × head geometry) and
masking regimes, per the session's testing contract.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import chunk_attn, merge2, ref, router_score
from compile.kernels.chunk_attn import Q_TILE

RTOL, ATOL = 1e-5, 1e-5


def _mk(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def assert_partials_close(got, want):
    """Compare (o, m, l) partials; -inf == -inf counts as equal for m."""
    o1, m1, l1 = (np.asarray(x) for x in got)
    o2, m2, l2 = (np.asarray(x) for x in want)
    np.testing.assert_allclose(o1, o2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(l1, l2, rtol=RTOL, atol=ATOL)
    both_inf = np.isneginf(m1) & np.isneginf(m2)
    np.testing.assert_array_equal(np.isneginf(m1), np.isneginf(m2))
    np.testing.assert_allclose(
        np.where(both_inf, 0.0, m1), np.where(both_inf, 0.0, m2),
        rtol=RTOL, atol=ATOL,
    )


# Batch sizes the kernel's query tiling accepts: divisible by min(b, Q_TILE).
VALID_B = [b for b in range(1, 33) if b % min(b, Q_TILE) == 0]


@settings(max_examples=40, deadline=None)
@given(
    b=st.sampled_from(VALID_B),
    c=st.sampled_from([16, 32, 64, 128]),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    k_base=st.integers(0, 200),
    valid_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunk_attn_matches_ref(b, c, hkv, group, dh, k_base, valid_frac, seed):
    """Pallas Shared-KV attention == oracle across the shape/mask space."""
    rng = np.random.default_rng(seed)
    h = hkv * group
    q = _mk(rng, b, h, dh)
    k = _mk(rng, c, hkv, dh)
    v = _mk(rng, c, hkv, dh)
    # positions span the interesting regimes: before / inside / after chunk,
    # plus explicit padding rows.
    q_pos = rng.integers(-1, k_base + c + 50, size=b).astype(np.int32)
    valid = np.array([max(1, int(c * valid_frac))], np.int32)
    kb = np.array([k_base], np.int32)
    got = chunk_attn(q, k, v, jnp.asarray(q_pos), jnp.asarray(kb),
                     jnp.asarray(valid))
    want = ref.chunk_attn_ref(q, k, v, jnp.asarray(q_pos), jnp.asarray(kb),
                              jnp.asarray(valid))
    assert_partials_close(got, want)


def test_chunk_attn_all_masked_rows():
    """Padding rows (q_pos = -1) must emit the merge identity (0, -inf, 0)."""
    rng = np.random.default_rng(7)
    q, k, v = _mk(rng, 4, 4, 16), _mk(rng, 64, 2, 16), _mk(rng, 64, 2, 16)
    q_pos = jnp.asarray([-1, -1, -1, -1], jnp.int32)
    o, m, l = chunk_attn(q, k, v, q_pos, jnp.asarray([0], jnp.int32),
                         jnp.asarray([64], jnp.int32))
    assert np.all(np.asarray(o) == 0.0)
    assert np.all(np.isneginf(np.asarray(m)))
    assert np.all(np.asarray(l) == 0.0)


def test_chunk_attn_future_chunk_masked():
    """A chunk entirely in the future of every query is fully masked."""
    rng = np.random.default_rng(8)
    q, k, v = _mk(rng, 2, 4, 16), _mk(rng, 64, 2, 16), _mk(rng, 64, 2, 16)
    q_pos = jnp.asarray([10, 50], jnp.int32)  # both < k_base
    o, m, l = chunk_attn(q, k, v, q_pos, jnp.asarray([100], jnp.int32),
                         jnp.asarray([64], jnp.int32))
    assert np.all(np.asarray(l) == 0.0)
    assert np.all(np.isneginf(np.asarray(m)))


def test_chunk_attn_decode_vs_softmax():
    """B=1 decode against one fully-visible chunk == plain softmax attn."""
    rng = np.random.default_rng(9)
    q, k, v = _mk(rng, 1, 4, 16), _mk(rng, 64, 2, 16), _mk(rng, 64, 2, 16)
    q_pos = jnp.asarray([1000], jnp.int32)
    o, m, l = chunk_attn(q, k, v, q_pos, jnp.asarray([0], jnp.int32),
                         jnp.asarray([64], jnp.int32))
    out = np.asarray(ref.finalize_ref(o, l))
    want = np.asarray(
        ref.full_attn_ref(q, k, v, q_pos, jnp.arange(64, dtype=jnp.int32))
    )
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([64, 128, 192, 256]),
    chunk=st.sampled_from([32, 64]),
    b=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_equals_full(t, chunk, b, seed):
    """THE decomposition property: LSE-merged chunk partials == full attn.

    This is what makes the whole MoSKA serving scheme exact (when routing
    is dense): attention over any context equals the merge of per-chunk
    Shared-KV attention calls.
    """
    rng = np.random.default_rng(seed)
    hkv, h, dh = 2, 4, 16
    q = _mk(rng, b, h, dh)
    k = _mk(rng, t, hkv, dh)
    v = _mk(rng, t, hkv, dh)
    q_pos = jnp.asarray(rng.integers(0, t + 10, size=b), jnp.int32)
    k_pos = jnp.arange(t, dtype=jnp.int32)
    want = ref.full_attn_ref(q, k, v, q_pos, k_pos)
    parts = [
        chunk_attn(q, k[s : s + chunk], v[s : s + chunk], q_pos,
                   jnp.asarray([s], jnp.int32),
                   jnp.asarray([min(chunk, t - s)], jnp.int32))
        for s in range(0, t, chunk)
    ]
    o, m, l = ref.merge_ref(parts)
    got = ref.finalize_ref(o, l)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 4, 8]),
    h=st.sampled_from([2, 4]),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_merge2_matches_ref(b, h, dh, seed):
    rng = np.random.default_rng(seed)
    def part():
        o = _mk(rng, b, h, dh)
        m = _mk(rng, b, h)
        l = jnp.abs(_mk(rng, b, h)) + 0.1
        return o, m, l
    p1, p2 = part(), part()
    got = merge2(*p1, *p2)
    want = ref.merge2_ref(*p1, *p2)
    assert_partials_close(got, want)


def test_merge2_identity():
    """Merging with the (0, -inf, 0) identity is a no-op."""
    rng = np.random.default_rng(11)
    b, h, dh = 4, 4, 16
    o, m, l = _mk(rng, b, h, dh), _mk(rng, b, h), jnp.abs(_mk(rng, b, h))
    zo = jnp.zeros((b, h, dh), jnp.float32)
    zm = jnp.full((b, h), -jnp.inf, jnp.float32)
    zl = jnp.zeros((b, h), jnp.float32)
    got = merge2(o, m, l, zo, zm, zl)
    assert_partials_close(got, (o, m, l))
    got = merge2(zo, zm, zl, o, m, l)
    assert_partials_close(got, (o, m, l))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_merge_order_invariance(n, seed):
    """Merging partials in any order gives the same normalized output."""
    rng = np.random.default_rng(seed)
    b, h, dh = 2, 4, 8
    parts = []
    for _ in range(n):
        o = _mk(rng, b, h, dh)
        m = _mk(rng, b, h)
        l = jnp.abs(_mk(rng, b, h)) + 0.1
        parts.append((o, m, l))
    o1, _, l1 = ref.merge_ref(parts)
    perm = list(rng.permutation(n))
    o2, _, l2 = ref.merge_ref([parts[i] for i in perm])
    np.testing.assert_allclose(
        np.asarray(ref.finalize_ref(o1, l1)),
        np.asarray(ref.finalize_ref(o2, l2)),
        rtol=1e-4, atol=1e-5,
    )


@settings(max_examples=30, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8, 16, 32]),
    c=st.sampled_from([16, 64, 256]),
    hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_router_matches_ref(b, c, hkv, group, seed):
    rng = np.random.default_rng(seed)
    h, dh = hkv * group, 16
    q = _mk(rng, b, h, dh)
    embs = _mk(rng, c, hkv, dh)
    got = router_score(q, embs)
    want = ref.router_score_ref(q, embs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_router_prefers_aligned_chunk():
    """A chunk embedding equal to the query direction scores highest."""
    b, hkv, group, dh = 1, 2, 2, 16
    h = hkv * group
    rng = np.random.default_rng(13)
    q = _mk(rng, b, h, dh)
    embs = np.asarray(_mk(rng, 8, hkv, dh)) * 0.01
    # chunk 5 = mean of the query's kv-grouped vectors, scaled up.
    qk = np.asarray(q).reshape(hkv, group, dh).mean(axis=1)
    embs[5] = qk * 10.0
    scores = np.asarray(router_score(q, jnp.asarray(embs)))
    assert scores[0].argmax() == 5
