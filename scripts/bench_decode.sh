#!/usr/bin/env bash
# Decode-throughput perf gate: runs the e2e_serving bench (native
# parallel-decode section needs no artifacts) and drops the perf
# trajectory at BENCH_decode.json in the repo root, so successive PRs
# can compare tokens/sec and the serial→parallel speedup.
#
# Also runs `cargo fmt --check` and `cargo clippy -- -D warnings` when
# those components are installed. Lint failures are reported and, with
# --strict, fatal; the bench result is always the exit-status gate.
#
# Usage: scripts/bench_decode.sh [--strict]

set -u
cd "$(dirname "$0")/.."

STRICT=0
[ "${1:-}" = "--strict" ] && STRICT=1

# the cargo workspace lives under rust/ (fall back to repo root)
WORKDIR=.
if [ -f rust/Cargo.toml ]; then
    WORKDIR=rust
elif [ ! -f Cargo.toml ] && [ -d rust ]; then
    WORKDIR=rust
fi
cd "$WORKDIR"

LINT_RC=0
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check || LINT_RC=1
else
    echo "cargo fmt not installed — skipping format check"
fi
if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings || LINT_RC=1
else
    echo "cargo clippy not installed — skipping lint"
fi
if [ $LINT_RC -ne 0 ]; then
    echo "lint problems found$( [ $STRICT -eq 1 ] && echo ' (strict: failing)' )"
    [ $STRICT -eq 1 ] && exit 1
fi

echo "== kernel microbench (scalar vs lanes8 vs detected SIMD) =="
cargo bench --bench kernels || exit 1

KOUT=bench_out/BENCH_kernels.json
if [ -f "$KOUT" ]; then
    cp "$KOUT" ../BENCH_kernels.json 2>/dev/null || cp "$KOUT" BENCH_kernels.json
    echo "kernel trajectory:"
    cat "$KOUT"
    echo
else
    echo "error: $KOUT was not produced" >&2
    exit 1
fi

echo "== e2e_serving bench (native decode section) =="
cargo bench --bench e2e_serving || exit 1

OUT=bench_out/BENCH_decode.json
if [ -f "$OUT" ]; then
    cp "$OUT" ../BENCH_decode.json 2>/dev/null || cp "$OUT" BENCH_decode.json
    echo "perf trajectory:"
    cat "$OUT"
    echo
else
    echo "error: $OUT was not produced" >&2
    exit 1
fi
