#!/usr/bin/env bash
# CI gate: doc-link check, format, lint, tests, bench smoke, and the
# remote-node / tracing / 2-shard loopback smokes — the same checks
# every PR must clear, runnable locally and on any runner with a rust
# toolchain.
#
#   scripts/ci.sh            # run everything, fail on any problem
#   scripts/ci.sh --no-bench # skip the bench smoke (fast pre-push)
#
# Components that are not installed (fmt/clippy on minimal toolchains)
# fail the gate loudly ONLY if CI_REQUIRE_LINT=1; by default they are
# reported and skipped so the test gate still runs everywhere.

set -u
cd "$(dirname "$0")/.."

RUN_BENCH=1
[ "${1:-}" = "--no-bench" ] && RUN_BENCH=0
REQUIRE_LINT="${CI_REQUIRE_LINT:-0}"

# the cargo workspace lives under rust/ (fall back to repo root)
WORKDIR=.
if [ -f rust/Cargo.toml ] || { [ ! -f Cargo.toml ] && [ -d rust ]; }; then
    WORKDIR=rust
fi
cd "$WORKDIR"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH" >&2
    exit 1
fi

FAIL=0

echo "== doc-link check =="
# every docs/*.md path referenced from module docs / READMEs must exist
# (paths are repo-root-relative; we're in $WORKDIR, so look one level up
# when needed)
DOC_REFS=$(grep -rhoE 'docs/[A-Za-z0-9_.-]+\.md' \
               src ../README.md ../scripts README.md 2>/dev/null | sort -u)
for ref in $DOC_REFS; do
    if [ ! -f "../$ref" ] && [ ! -f "$ref" ]; then
        echo "error: referenced doc $ref does not exist" >&2
        FAIL=1
    fi
done
echo "checked $(echo "$DOC_REFS" | grep -c .) referenced doc paths"

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check || FAIL=1
elif [ "$REQUIRE_LINT" = "1" ]; then
    echo "cargo fmt missing (CI_REQUIRE_LINT=1)"; FAIL=1
else
    echo "cargo fmt not installed — skipping format check"
fi

echo "== cargo clippy -- -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings || FAIL=1
elif [ "$REQUIRE_LINT" = "1" ]; then
    echo "cargo clippy missing (CI_REQUIRE_LINT=1)"; FAIL=1
else
    echo "cargo clippy not installed — skipping lint"
fi

echo "== cargo test -q (MOSKA_KERNEL=scalar) =="
MOSKA_KERNEL=scalar cargo test -q || FAIL=1

echo "== cargo test -q (MOSKA_KERNEL=simd) =="
MOSKA_KERNEL=simd cargo test -q || FAIL=1

if [ "$RUN_BENCH" = "1" ]; then
    echo "== bench smoke: e2e_serving (native decode section) =="
    # the native section needs no artifacts and asserts serial/parallel
    # bit-identity + emits bench_out/BENCH_decode.json
    cargo bench --bench e2e_serving || FAIL=1
    if [ -f bench_out/BENCH_decode.json ]; then
        echo "perf trajectory:"
        cat bench_out/BENCH_decode.json
        echo
    else
        echo "error: bench_out/BENCH_decode.json was not produced" >&2
        FAIL=1
    fi
fi

if [ "$RUN_BENCH" = "1" ]; then
    echo "== kernel flavor A/B smoke =="
    # the SIMD-layer acceptance surface: bit-identical decode tokens
    # across MOSKA_KERNEL=scalar|simd|lanes8 AND across thread counts
    # (the simd run uses 2 threads, the others 1)
    if cargo build --release --bin moska; then
        BIN=target/release/moska
        mkdir -p bench_out
        if MOSKA_KERNEL=scalar "$BIN" disagg --synthetic --batches 2,4 \
               --steps 4 --threads 1 \
               --emit-tokens bench_out/tokens_scalar.json \
           && MOSKA_KERNEL=simd "$BIN" disagg --synthetic --batches 2,4 \
               --steps 4 --threads 2 \
               --emit-tokens bench_out/tokens_simd.json \
           && MOSKA_KERNEL=lanes8 "$BIN" disagg --synthetic --batches 2,4 \
               --steps 4 --threads 1 \
               --emit-tokens bench_out/tokens_lanes8.json; then
            if cmp -s bench_out/tokens_scalar.json \
                      bench_out/tokens_simd.json \
               && cmp -s bench_out/tokens_scalar.json \
                        bench_out/tokens_lanes8.json; then
                echo "kernel A/B smoke: tokens bit-identical across \
scalar|simd|lanes8 and thread counts"
            else
                echo "error: decode tokens diverged across kernel flavors" >&2
                FAIL=1
            fi
        else
            echo "error: kernel A/B smoke run failed" >&2
            FAIL=1
        fi
    else
        echo "error: release build for the kernel A/B smoke failed" >&2
        FAIL=1
    fi
fi

if [ "$RUN_BENCH" = "1" ]; then
    echo "== kv-dtype matrix smoke =="
    # the precision layer's serving gate: under f32 — default, env
    # (MOSKA_KV_DTYPE), or CLI (--kv-dtype) — the synthetic disagg
    # token JSON is bit-identical to the seed run; f16/bf16 may round
    # differently but must pass the bounded token-divergence gate
    # (same stream structure, at most half the token positions differ
    # — greedy flips cascade, so the gate exists to catch crashes,
    # empty output, and catastrophic widening bugs); int8 must decode.
    if cargo build --release --bin moska; then
        BIN=target/release/moska
        mkdir -p bench_out
        DT_OK=1
        "$BIN" disagg --synthetic --batches 2,4 --steps 4 --threads 1 \
            --emit-tokens bench_out/tokens_dt_seed.json || DT_OK=0
        MOSKA_KV_DTYPE=f32 "$BIN" disagg --synthetic --batches 2,4 \
            --steps 4 --threads 1 \
            --emit-tokens bench_out/tokens_dt_f32env.json || DT_OK=0
        "$BIN" disagg --synthetic --batches 2,4 --steps 4 --threads 1 \
            --kv-dtype f32 \
            --emit-tokens bench_out/tokens_dt_f32cli.json || DT_OK=0
        if [ "$DT_OK" = "1" ] \
           && cmp -s bench_out/tokens_dt_seed.json \
                     bench_out/tokens_dt_f32env.json \
           && cmp -s bench_out/tokens_dt_seed.json \
                     bench_out/tokens_dt_f32cli.json; then
            echo "kv-dtype smoke: f32 (default|env|CLI) bit-identical"
        else
            echo "error: f32 kv-dtype run diverged from the seed run" >&2
            FAIL=1
        fi
        for DT in f16 bf16 int8; do
            if ! "$BIN" disagg --synthetic --batches 2,4 --steps 4 \
                     --threads 1 --kv-dtype "$DT" \
                     --emit-tokens "bench_out/tokens_dt_$DT.json"; then
                echo "error: --kv-dtype $DT run failed" >&2
                FAIL=1
                continue
            fi
            # int8 quantization may legitimately diverge further; its
            # gate is decode-completes (plus the tier-1 property tests)
            [ "$DT" = "int8" ] && continue
            grep -oE '\-?[0-9]+' bench_out/tokens_dt_seed.json \
                > bench_out/dt_seed.toks
            grep -oE '\-?[0-9]+' "bench_out/tokens_dt_$DT.json" \
                > "bench_out/dt_$DT.toks"
            N=$(wc -l < bench_out/dt_seed.toks | tr -d ' ')
            M=$(wc -l < "bench_out/dt_$DT.toks" | tr -d ' ')
            if [ "$N" != "$M" ] || [ "$N" -eq 0 ]; then
                echo "error: $DT token stream structure diverged \
($M vs $N values)" >&2
                FAIL=1
                continue
            fi
            DIFFS=$(paste bench_out/dt_seed.toks \
                          "bench_out/dt_$DT.toks" \
                    | awk '$1 != $2 { d++ } END { print d + 0 }')
            if [ $((DIFFS * 2)) -le "$N" ]; then
                echo "kv-dtype smoke: $DT diverged at $DIFFS/$N token \
positions (within the 50% gate)"
            else
                echo "error: $DT diverged at $DIFFS/$N token positions \
(> 50%)" >&2
                FAIL=1
            fi
        done
    else
        echo "error: release build for the kv-dtype smoke failed" >&2
        FAIL=1
    fi
fi

if [ "$RUN_BENCH" = "1" ]; then
    echo "== remote-node loopback smoke =="
    # spawn a real `moska shared-node` process on an ephemeral loopback
    # port, run the same short synthetic disagg decode in-process and
    # over the socket, and require bit-identical token streams
    if cargo build --release --bin moska; then
        BIN=target/release/moska
        mkdir -p bench_out
        # ephemeral port: the node prints "listening on <addr>" once bound
        "$BIN" shared-node --synthetic --addr 127.0.0.1:0 \
            > bench_out/shared_node.log 2>&1 &
        NODE_PID=$!
        trap 'kill "$NODE_PID" 2>/dev/null' EXIT
        ADDR=""
        for _ in $(seq 1 100); do
            ADDR=$(sed -n 's/^shared-node listening on \([0-9.:]*\).*/\1/p' \
                       bench_out/shared_node.log 2>/dev/null | head -1)
            [ -n "$ADDR" ] && break
            sleep 0.1
        done
        if [ -z "$ADDR" ]; then
            echo "error: shared-node never reported its address" >&2
            cat bench_out/shared_node.log >&2 || true
            FAIL=1
        elif "$BIN" disagg --synthetic --batches 2,4 --steps 4 --threads 1 \
               --remote "$ADDR" \
               --emit-tokens bench_out/remote_tokens.json \
           && "$BIN" disagg --synthetic --batches 2,4 --steps 4 --threads 1 \
               --emit-tokens bench_out/local_tokens.json; then
            if cmp -s bench_out/remote_tokens.json \
                      bench_out/local_tokens.json; then
                echo "remote-node smoke: token streams bit-identical"
            else
                echo "error: remote decode diverged from in-process run" >&2
                FAIL=1
            fi
        else
            echo "error: remote-node smoke run failed" >&2
            cat bench_out/shared_node.log >&2 || true
            FAIL=1
        fi
        kill "$NODE_PID" 2>/dev/null
        trap - EXIT
    else
        echo "error: release build for the remote smoke failed" >&2
        FAIL=1
    fi
fi

if [ "$RUN_BENCH" = "1" ]; then
    echo "== tracing smoke =="
    # a traced loopback `disagg --remote` run must (a) decode tokens
    # bit-identical to the untraced run (tracing is observation only)
    # and (b) write a Chrome-trace JSON holding the client's spans AND
    # the shared node's echoed spans under one trace id
    if cargo build --release --bin moska; then
        BIN=target/release/moska
        mkdir -p bench_out
        "$BIN" shared-node --synthetic --addr 127.0.0.1:0 \
            > bench_out/trace_node.log 2>&1 &
        NODE_PID=$!
        trap 'kill "$NODE_PID" 2>/dev/null' EXIT
        ADDR=""
        for _ in $(seq 1 100); do
            ADDR=$(sed -n 's/^shared-node listening on \([0-9.:]*\).*/\1/p' \
                       bench_out/trace_node.log 2>/dev/null | head -1)
            [ -n "$ADDR" ] && break
            sleep 0.1
        done
        if [ -z "$ADDR" ]; then
            echo "error: trace-smoke node never reported its address" >&2
            cat bench_out/trace_node.log >&2 || true
            FAIL=1
        elif "$BIN" disagg --synthetic --batches 2,4 --steps 4 --threads 1 \
               --remote "$ADDR" --trace bench_out/trace_remote.json \
               --emit-tokens bench_out/traced_tokens.json \
           && "$BIN" disagg --synthetic --batches 2,4 --steps 4 --threads 1 \
               --remote "$ADDR" \
               --emit-tokens bench_out/untraced_tokens.json; then
            if cmp -s bench_out/traced_tokens.json \
                      bench_out/untraced_tokens.json; then
                echo "tracing smoke: tokens bit-identical traced/untraced"
            else
                echo "error: tracing changed the decoded tokens" >&2
                FAIL=1
            fi
            if command -v python3 >/dev/null 2>&1; then
                if python3 - bench_out/trace_remote.json <<'PYEOF'
import json, sys
t = json.load(open(sys.argv[1]))
evs = t["traceEvents"]
tid = t["otherData"]["trace_id"]
assert tid.startswith("0x") and int(tid, 16) != 0, tid
xs = [e for e in evs if e.get("ph") == "X"]
assert xs, "no duration events"
assert all(e["dur"] >= 0 for e in xs), "negative span duration"
names = {e["name"] for e in xs}
assert "decode.step" in names, sorted(names)
assert "fabric.send" in names, sorted(names)
remote = [e for e in xs if e.get("cat") == "remote"]
assert remote, "no echoed shared-node spans"
assert all(e["pid"] >= 2 for e in remote), "remote span on client pid"
print("trace ok: %d events (%d remote), trace id %s"
      % (len(evs), len(remote), tid))
PYEOF
                then
                    echo "tracing smoke: stitched trace validated"
                else
                    echo "error: trace JSON failed validation" >&2
                    FAIL=1
                fi
            else
                # no python3 on the runner: structural spot checks only
                if grep -q '"traceEvents"' bench_out/trace_remote.json \
                   && grep -q '"decode.step"' bench_out/trace_remote.json \
                   && grep -q '"remote"' bench_out/trace_remote.json \
                   && grep -q '"trace_id"' bench_out/trace_remote.json; then
                    echo "tracing smoke: trace spot-checked (no python3)"
                else
                    echo "error: trace JSON missing expected spans" >&2
                    FAIL=1
                fi
            fi
        else
            echo "error: tracing smoke run failed" >&2
            cat bench_out/trace_node.log >&2 || true
            FAIL=1
        fi
        kill "$NODE_PID" 2>/dev/null
        trap - EXIT
    else
        echo "error: release build for the tracing smoke failed" >&2
        FAIL=1
    fi
fi

if [ "$RUN_BENCH" = "1" ]; then
    echo "== 2-shard loopback smoke =="
    # two `moska shared-node` processes each serving a PARTITIONED slice
    # of the synthetic store (--domains), a sharded disagg run against
    # them (planner state synced over the wire — the unique node holds
    # no shared K/V), and an in-process run with the same domain mix:
    # the token JSONs must be bit-identical
    if cargo build --release --bin moska; then
        BIN=target/release/moska
        mkdir -p bench_out
        "$BIN" shared-node --synthetic --domains bench \
            --addr 127.0.0.1:0 > bench_out/shard_a.log 2>&1 &
        SHARD_A_PID=$!
        "$BIN" shared-node --synthetic --domains bench2 \
            --addr 127.0.0.1:0 > bench_out/shard_b.log 2>&1 &
        SHARD_B_PID=$!
        trap 'kill "$SHARD_A_PID" "$SHARD_B_PID" 2>/dev/null' EXIT
        ADDR_A=""
        ADDR_B=""
        for _ in $(seq 1 100); do
            ADDR_A=$(sed -n 's/^shared-node listening on \([0-9.:]*\).*/\1/p' \
                         bench_out/shard_a.log 2>/dev/null | head -1)
            ADDR_B=$(sed -n 's/^shared-node listening on \([0-9.:]*\).*/\1/p' \
                         bench_out/shard_b.log 2>/dev/null | head -1)
            [ -n "$ADDR_A" ] && [ -n "$ADDR_B" ] && break
            sleep 0.1
        done
        if [ -z "$ADDR_A" ] || [ -z "$ADDR_B" ]; then
            echo "error: shard nodes never reported their addresses" >&2
            cat bench_out/shard_a.log bench_out/shard_b.log >&2 || true
            FAIL=1
        elif "$BIN" disagg --synthetic --batches 2,4 --steps 4 --threads 1 \
               --domains bench,bench2 --shards "$ADDR_A,$ADDR_B" \
               --emit-tokens bench_out/sharded_tokens.json \
           && "$BIN" disagg --synthetic --batches 2,4 --steps 4 --threads 1 \
               --domains bench,bench2 \
               --emit-tokens bench_out/local_mixed_tokens.json; then
            if cmp -s bench_out/sharded_tokens.json \
                      bench_out/local_mixed_tokens.json; then
                echo "2-shard smoke: token streams bit-identical"
            else
                echo "error: sharded decode diverged from in-process run" >&2
                FAIL=1
            fi
        else
            echo "error: 2-shard smoke run failed" >&2
            cat bench_out/shard_a.log bench_out/shard_b.log >&2 || true
            FAIL=1
        fi
        kill "$SHARD_A_PID" "$SHARD_B_PID" 2>/dev/null
        trap - EXIT
    else
        echo "error: release build for the 2-shard smoke failed" >&2
        FAIL=1
    fi
fi

if [ "$RUN_BENCH" = "1" ]; then
    echo "== chaos failover smoke =="
    # two FULL-store replica nodes (no --domains): every domain is a
    # 2-replica set. SIGTERM one replica mid-run — the sharded run must
    # fail over to the survivor, exit 0, and stay bit-identical to the
    # in-process run; the killed node must drain and exit 0. Then kill
    # the ONLY node of a 1-shard run — per-request errors, still exit 0.
    if cargo build --release --bin moska; then
        BIN=target/release/moska
        mkdir -p bench_out
        "$BIN" shared-node --synthetic --addr 127.0.0.1:0 \
            > bench_out/replica_a.log 2>&1 &
        REP_A_PID=$!
        "$BIN" shared-node --synthetic --addr 127.0.0.1:0 \
            > bench_out/replica_b.log 2>&1 &
        REP_B_PID=$!
        trap 'kill "$REP_A_PID" "$REP_B_PID" 2>/dev/null' EXIT
        ADDR_A=""
        ADDR_B=""
        for _ in $(seq 1 100); do
            ADDR_A=$(sed -n 's/^shared-node listening on \([0-9.:]*\).*/\1/p' \
                         bench_out/replica_a.log 2>/dev/null | head -1)
            ADDR_B=$(sed -n 's/^shared-node listening on \([0-9.:]*\).*/\1/p' \
                         bench_out/replica_b.log 2>/dev/null | head -1)
            [ -n "$ADDR_A" ] && [ -n "$ADDR_B" ] && break
            sleep 0.1
        done
        # many short points: the kill fires after the FIRST finished
        # point, with 11 more still ahead of the run
        CHAOS_BATCHES=2,4,2,4,2,4,2,4,2,4,2,4
        if [ -z "$ADDR_A" ] || [ -z "$ADDR_B" ]; then
            echo "error: replica nodes never reported their addresses" >&2
            cat bench_out/replica_a.log bench_out/replica_b.log >&2 || true
            FAIL=1
        else
            "$BIN" disagg --synthetic --batches "$CHAOS_BATCHES" \
                --steps 8 --threads 1 --domains bench,bench2 \
                --shards "$ADDR_A,$ADDR_B" \
                --emit-tokens bench_out/chaos_tokens.json \
                > bench_out/chaos_run.log 2>&1 &
            RUN_PID=$!
            KILLED=0
            for _ in $(seq 1 1500); do
                kill -0 "$RUN_PID" 2>/dev/null || break
                if grep -q "point done: batch" bench_out/chaos_run.log \
                       2>/dev/null; then
                    kill -TERM "$REP_A_PID" 2>/dev/null
                    KILLED=1
                    break
                fi
                sleep 0.02
            done
            if [ "$KILLED" -ne 1 ]; then
                echo "error: chaos run never reported a finished point" >&2
                cat bench_out/chaos_run.log >&2 || true
                kill "$RUN_PID" 2>/dev/null
                FAIL=1
            elif wait "$RUN_PID"; then
                if wait "$REP_A_PID"; then
                    echo "chaos smoke: SIGTERM'd replica drained, exit 0"
                else
                    echo "error: SIGTERM'd replica exited non-zero" >&2
                    cat bench_out/replica_a.log >&2 || true
                    FAIL=1
                fi
                "$BIN" disagg --synthetic --batches "$CHAOS_BATCHES" \
                    --steps 8 --threads 1 --domains bench,bench2 \
                    --emit-tokens bench_out/chaos_local_tokens.json \
                    > /dev/null 2>&1
                if cmp -s bench_out/chaos_tokens.json \
                          bench_out/chaos_local_tokens.json; then
                    echo "chaos smoke: post-failover tokens bit-identical"
                else
                    echo "error: decode diverged after replica kill" >&2
                    FAIL=1
                fi
                FO=$(sed -n \
                         's/.*fabric elastic: failovers=\([0-9]*\).*/\1/p' \
                         bench_out/chaos_run.log | head -1)
                if [ -n "$FO" ] && [ "$FO" -ge 1 ]; then
                    echo "chaos smoke: $FO failover(s) recorded"
                else
                    echo "error: no failover recorded (failovers=${FO:-?})" >&2
                    cat bench_out/chaos_run.log >&2 || true
                    FAIL=1
                fi
            else
                echo "error: chaos run aborted — killing one of two \
replicas must not fail the run" >&2
                cat bench_out/chaos_run.log >&2 || true
                FAIL=1
            fi
        fi
        kill "$REP_B_PID" 2>/dev/null
        trap - EXIT

        # --- no-survivor case: the ONLY replica dies → per-request
        # errors on stderr, run still exits 0 (never a process abort)
        "$BIN" shared-node --synthetic --addr 127.0.0.1:0 \
            > bench_out/solo_node.log 2>&1 &
        SOLO_PID=$!
        trap 'kill "$SOLO_PID" 2>/dev/null' EXIT
        ADDR_S=""
        for _ in $(seq 1 100); do
            ADDR_S=$(sed -n 's/^shared-node listening on \([0-9.:]*\).*/\1/p' \
                         bench_out/solo_node.log 2>/dev/null | head -1)
            [ -n "$ADDR_S" ] && break
            sleep 0.1
        done
        if [ -z "$ADDR_S" ]; then
            echo "error: solo node never reported its address" >&2
            FAIL=1
        else
            "$BIN" disagg --synthetic --batches "$CHAOS_BATCHES" \
                --steps 8 --threads 1 --domains bench,bench2 \
                --shards "$ADDR_S" \
                > bench_out/chaos_solo.log 2>&1 &
            RUN_PID=$!
            KILLED=0
            for _ in $(seq 1 1500); do
                kill -0 "$RUN_PID" 2>/dev/null || break
                if grep -q "point done: batch" bench_out/chaos_solo.log \
                       2>/dev/null; then
                    kill -TERM "$SOLO_PID" 2>/dev/null
                    KILLED=1
                    break
                fi
                sleep 0.02
            done
            if [ "$KILLED" -ne 1 ]; then
                echo "error: solo chaos run never reported a point" >&2
                cat bench_out/chaos_solo.log >&2 || true
                kill "$RUN_PID" 2>/dev/null
                FAIL=1
            elif wait "$RUN_PID"; then
                if grep -q "no surviving replica" \
                        bench_out/chaos_solo.log; then
                    echo "chaos smoke: lost last replica → per-request \
errors, exit 0"
                else
                    echo "error: no per-request DomainUnavailable \
reported after losing the last replica" >&2
                    cat bench_out/chaos_solo.log >&2 || true
                    FAIL=1
                fi
            else
                echo "error: losing the last replica aborted the run \
(must degrade to per-request errors)" >&2
                cat bench_out/chaos_solo.log >&2 || true
                FAIL=1
            fi
        fi
        kill "$SOLO_PID" 2>/dev/null
        trap - EXIT
    else
        echo "error: release build for the chaos smoke failed" >&2
        FAIL=1
    fi
fi

if [ "$RUN_BENCH" = "1" ]; then
    echo "== serving loop smoke =="
    # a real `moska serve --synthetic` on an ephemeral loopback port,
    # driven by `moska loadgen` for a few seconds of SSE traffic; the
    # gate: zero request errors, nonzero streamed tokens, and finite
    # TTFT/TPOT percentiles in bench_out/BENCH_serving.json (plus the
    # chunked-vs-unchunked TTFT probe riding in the same report)
    if cargo build --release --bin moska; then
        BIN=target/release/moska
        mkdir -p bench_out
        "$BIN" serve --synthetic --addr 127.0.0.1:0 \
            > bench_out/serve.log 2>&1 &
        SRV_PID=$!
        trap 'kill "$SRV_PID" 2>/dev/null' EXIT
        ADDR=""
        for _ in $(seq 1 100); do
            ADDR=$(sed -n 's/.*listening on http:\/\/\([0-9.:]*\).*/\1/p' \
                       bench_out/serve.log 2>/dev/null | head -1)
            [ -n "$ADDR" ] && break
            sleep 0.1
        done
        if [ -z "$ADDR" ]; then
            echo "error: serve never reported its address" >&2
            cat bench_out/serve.log >&2 || true
            FAIL=1
        elif "$BIN" loadgen --addr "$ADDR" --scenario rag-shared \
                 --seconds 5 --concurrency 4 \
                 --out bench_out/BENCH_serving.json --compare-chunking; then
            if command -v python3 >/dev/null 2>&1; then
                if python3 - bench_out/BENCH_serving.json <<'PYEOF'
import json, math, sys
r = json.load(open(sys.argv[1]))
assert r["errors"] == 0, "request errors: %s" % r.get("first_error", r)
assert r["requests"] > 0, r
assert r["streamed_tokens"] > 0, r
for k in ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms", "tpot_p99_ms",
          "goodput_rps"):
    v = r[k]
    assert isinstance(v, (int, float)) and math.isfinite(v) and v >= 0, \
        (k, v)
cc = r.get("chunking_compare")
assert cc, "chunking probe missing from the report"
assert cc["short_ttft_speedup"] > 0, cc
print("serving ok: %d req, %d streamed tokens, ttft p50 %.2f ms "
      "p99 %.2f ms, chunked short-TTFT speedup %.2fx"
      % (r["requests"], r["streamed_tokens"], r["ttft_p50_ms"],
         r["ttft_p99_ms"], cc["short_ttft_speedup"]))
PYEOF
                then
                    echo "serving smoke: report gate passed"
                else
                    echo "error: BENCH_serving.json failed the gate" >&2
                    cat bench_out/BENCH_serving.json >&2 || true
                    FAIL=1
                fi
            else
                # no python3: the compact-JSON spot checks
                if grep -q '"errors":0,' bench_out/BENCH_serving.json \
                   && grep -q '"streamed_tokens":' \
                           bench_out/BENCH_serving.json \
                   && ! grep -q '"streamed_tokens":0,' \
                           bench_out/BENCH_serving.json \
                   && ! grep -qi 'nan\|inf' bench_out/BENCH_serving.json; then
                    echo "serving smoke: report spot-checked (no python3)"
                else
                    echo "error: BENCH_serving.json failed spot checks" >&2
                    cat bench_out/BENCH_serving.json >&2 || true
                    FAIL=1
                fi
            fi
        else
            echo "error: loadgen run against the server failed" >&2
            cat bench_out/serve.log >&2 || true
            FAIL=1
        fi
        kill "$SRV_PID" 2>/dev/null
        trap - EXIT
    else
        echo "error: release build for the serving smoke failed" >&2
        FAIL=1
    fi
fi

if [ "$RUN_BENCH" = "1" ]; then
    echo "== overload smoke (open-loop at 2x capacity) =="
    # a live `serve --synthetic` with tight admission watermarks, hit
    # with open-loop Poisson traffic at ~2x its measured closed-loop
    # goodput; the gate: batch work sheds, interactive work never
    # errors and keeps a finite p99 TTFT, the run exits cleanly, and
    # /stats drains back to zero afterwards
    if cargo build --release --bin moska; then
        BIN=target/release/moska
        mkdir -p bench_out
        "$BIN" serve --synthetic --addr 127.0.0.1:0 \
            --admission 0.1,0.05,128 \
            > bench_out/serve_overload.log 2>&1 &
        OSRV_PID=$!
        trap 'kill "$OSRV_PID" 2>/dev/null' EXIT
        ADDR_O=""
        for _ in $(seq 1 100); do
            ADDR_O=$(sed -n 's/.*listening on http:\/\/\([0-9.:]*\).*/\1/p' \
                         bench_out/serve_overload.log 2>/dev/null | head -1)
            [ -n "$ADDR_O" ] && break
            sleep 0.1
        done
        if [ -z "$ADDR_O" ]; then
            echo "error: overload server never reported its address" >&2
            cat bench_out/serve_overload.log >&2 || true
            FAIL=1
        # calibrate: closed-loop goodput under light concurrency ≈
        # server capacity (admission stays quiet at this depth)
        elif "$BIN" loadgen --addr "$ADDR_O" --scenario mixed \
                 --seconds 3 --concurrency 4 \
                 --out bench_out/BENCH_overload_cal.json; then
            GOODPUT=$(awk -F'"goodput_rps":' 'NF>1{split($2,a,/[,}]/);
                          print a[1]; exit}' \
                          bench_out/BENCH_overload_cal.json)
            RATE=$(awk "BEGIN{r=(${GOODPUT:-0})*2; if (r<4) r=4;
                        printf \"%.2f\", r}")
            echo "overload smoke: capacity ~${GOODPUT:-?} rps, \
open-loop at $RATE rps"
            if "$BIN" loadgen --addr "$ADDR_O" --scenario mixed \
                   --open-loop --rate "$RATE" --requests 80 \
                   --concurrency 16 \
                   --out bench_out/BENCH_overload.json; then
                if command -v python3 >/dev/null 2>&1; then
                    if python3 - bench_out/BENCH_overload.json \
                           "$ADDR_O" <<'PYEOF'
import json, math, sys, time, urllib.request
r = json.load(open(sys.argv[1]))
ol = r["open_loop"]
assert ol["offered"] == 80, ol
pc = ol["per_class"]
b, i = pc["batch"], pc["interactive"]
assert b["offered"] > 0 and i["offered"] > 0, pc
assert b["shed"] > 0, "no batch sheds at 2x capacity: %s" % b
assert i["errors"] == 0 and i["shed"] == 0, \
    "interactive work rejected/failed under overload: %s" % i
p99 = i["ttft_p99_ms"]
assert isinstance(p99, (int, float)) and math.isfinite(p99) and p99 >= 0, p99
assert ol.get("sheds_missing_retry_after", 0) == 0, ol
# post-run: the server must drain back to zero
deadline = time.time() + 15
while True:
    s = json.load(urllib.request.urlopen(
        "http://%s/stats" % sys.argv[2], timeout=5))
    if (s["live"] == 0 and s["queued"] == 0
            and s["kv_pages_allocated"] == 0):
        break
    assert time.time() < deadline, "server never drained: %s" % s
    time.sleep(0.2)
assert s["admission"]["shed_batch"] > 0, s["admission"]
print("overload ok: %d/%d completed, %d shed (%d batch), %d timeouts, "
      "interactive ttft p99 %.1f ms, server drained"
      % (ol["completed"], ol["offered"], ol["shed"], b["shed"],
         ol["timeouts"], p99))
PYEOF
                    then
                        echo "overload smoke: gate passed"
                    else
                        echo "error: BENCH_overload.json failed the gate" >&2
                        cat bench_out/BENCH_overload.json >&2 || true
                        FAIL=1
                    fi
                else
                    # no python3: compact-JSON spot checks (sheds
                    # happened, nothing errored, percentiles finite)
                    if grep -q '"open_loop":' bench_out/BENCH_overload.json \
                       && grep -q '"errors":0' \
                               bench_out/BENCH_overload.json \
                       && ! grep -q '"shed":0,"timeouts"' \
                               bench_out/BENCH_overload.json \
                       && ! grep -qi 'nan\|inf' \
                               bench_out/BENCH_overload.json; then
                        echo "overload smoke: spot-checked (no python3)"
                    else
                        echo "error: BENCH_overload.json failed spot \
checks" >&2
                        cat bench_out/BENCH_overload.json >&2 || true
                        FAIL=1
                    fi
                fi
            else
                echo "error: open-loop loadgen run failed" >&2
                cat bench_out/serve_overload.log >&2 || true
                FAIL=1
            fi
        else
            echo "error: calibration loadgen run failed" >&2
            cat bench_out/serve_overload.log >&2 || true
            FAIL=1
        fi
        kill "$OSRV_PID" 2>/dev/null
        trap - EXIT
    else
        echo "error: release build for the overload smoke failed" >&2
        FAIL=1
    fi
fi

if [ "$FAIL" -ne 0 ]; then
    echo "CI FAILED" >&2
    exit 1
fi
echo "CI OK"
